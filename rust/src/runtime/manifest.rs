//! `artifacts/manifest.json` parsing — the contract between the Python
//! compile path and this runtime. Every artifact entry lists its inputs
//! (name/shape/dtype) in the exact positional order the lowered HLO
//! expects; the weight loader and executor follow this order blindly.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, Precision};
use crate::util::json::{self, Value};

/// One positional input/output of a lowered executable.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDesc {
    /// Parameter name as lowered (e.g. `tokens`, `kv`, a weight name).
    pub name: String,
    /// Static shape.
    pub shape: Vec<usize>,
    /// Element type: "f32" | "i32" | "u8".
    pub dtype: String,
}

impl TensorDesc {
    /// Element count of the static shape.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    fn parse(v: &Value) -> Result<TensorDesc> {
        Ok(TensorDesc {
            name: v.get("name").as_str().context("desc name")?.to_string(),
            shape: v
                .get("shape")
                .as_arr()
                .context("desc shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect(),
            dtype: v.get("dtype").as_str().context("desc dtype")?.to_string(),
        })
    }
}

/// One compiled executable's bucket dimensions and I/O contract.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (also the executable-cache key).
    pub name: String,
    /// HLO text file name under the artifacts directory.
    pub file: String,
    /// Weight precision this executable was lowered for.
    pub precision: Precision,
    /// "prefill" | "decode" | "chunk"
    pub phase: String,
    /// Batch bucket (sequences per call).
    pub batch: usize,
    /// Sequence-length bucket: prompt rows (prefill) or chunk rows
    /// (chunk); 0 for decode.
    pub seq: usize,
    /// KV-prefix row bucket (chunk phase only; 0 otherwise). A chunk
    /// executable's `kv` input carries `prefix` cache rows per
    /// sequence, so chunks starting early ship fewer rows than the
    /// decode path's fixed `max_len`.
    pub prefix: usize,
    /// Positional input descriptors (leading activations, then every
    /// weight in canonical order).
    pub inputs: Vec<TensorDesc>,
    /// Positional output descriptors (`logits`, `kv_new`).
    pub outputs: Vec<TensorDesc>,
}

/// One model size's manifest entry: config + its artifacts.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Model architecture (the authoritative copy at runtime).
    pub config: ModelConfig,
    /// Every artifact lowered for this size (all precisions).
    pub artifacts: Vec<ArtifactMeta>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and HLO files) live in.
    pub dir: PathBuf,
    /// Model entries keyed by size name, in file order.
    pub models: Vec<(String, ModelEntry)>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let mut models = Vec::new();
        for (size, entry) in v.get("models").as_obj().context("models")? {
            let config = ModelConfig::from_manifest(entry.get("config"));
            let mut artifacts = Vec::new();
            for a in entry.get("artifacts").as_arr().context("artifacts")? {
                let precision = Precision::parse(
                    a.get("precision").as_str().context("precision")?,
                )
                .context("bad precision")?;
                artifacts.push(ArtifactMeta {
                    name: a.get("name").as_str().unwrap().to_string(),
                    file: a.get("file").as_str().unwrap().to_string(),
                    precision,
                    phase: a.get("phase").as_str().unwrap().to_string(),
                    batch: a.get("batch").as_usize().unwrap(),
                    seq: a.get("seq").as_usize().unwrap(),
                    // absent in pre-chunk manifests (and meaningless
                    // for prefill/decode): default 0
                    prefix: a.get("prefix").as_usize().unwrap_or(0),
                    inputs: a
                        .get("inputs")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(TensorDesc::parse)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(TensorDesc::parse)
                        .collect::<Result<_>>()?,
                });
            }
            models.push((size.clone(), ModelEntry { config, artifacts }));
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    /// The entry for one model size.
    pub fn model(&self, size: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|(s, _)| s == size)
            .map(|(_, e)| e)
            .with_context(|| format!("model size {size} not in manifest"))
    }

    /// Artifacts of one (size, precision).
    pub fn artifacts(&self, size: &str, precision: Precision)
        -> Result<Vec<&ArtifactMeta>> {
        Ok(self
            .model(size)?
            .artifacts
            .iter()
            .filter(|a| a.precision == precision)
            .collect())
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn hlo_path(&self, art: &ArtifactMeta) -> PathBuf {
        self.dir.join(&art.file)
    }
}

/// Default artifacts directory: `$SQPLUS_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("SQPLUS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Bail early with a clear message if artifacts are missing.
pub fn require_artifacts() -> Result<Manifest> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        bail!(
            "artifacts not found in {dir:?}; run `make artifacts` first \
             (or set SQPLUS_ARTIFACTS)"
        );
    }
    Manifest::load(&dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn loads_and_matches_configs() {
        let Some(m) = manifest() else { return };
        let e = m.model("tiny").unwrap();
        assert_eq!(e.config, ModelConfig::tiny());
        assert!(!e.artifacts.is_empty());
    }

    #[test]
    fn input_order_matches_canonical_weights() {
        let Some(m) = manifest() else { return };
        for (precision, namer) in [
            (Precision::Fp16,
             crate::model::weight_names as fn(&ModelConfig) -> Vec<String>),
            (Precision::W4a16, crate::model::weight_names_w4a16),
        ] {
            let arts = m.artifacts("tiny", precision).unwrap();
            let cfg = &m.model("tiny").unwrap().config;
            for a in arts {
                let skip = if a.phase == "prefill" { 2 } else { 3 };
                let got: Vec<&str> =
                    a.inputs[skip..].iter().map(|d| d.name.as_str()).collect();
                let want = namer(cfg);
                assert_eq!(got, want.iter().map(|s| s.as_str())
                    .collect::<Vec<_>>(), "{}", a.name);
            }
        }
    }

    #[test]
    fn decode_artifacts_have_kv_input() {
        let Some(m) = manifest() else { return };
        for a in m.artifacts("tiny", Precision::Fp16).unwrap() {
            if a.phase == "decode" {
                assert_eq!(a.inputs[2].name, "kv");
                let cfg = &m.model("tiny").unwrap().config;
                assert_eq!(a.inputs[2].shape,
                           vec![cfg.layers, 2, a.batch, cfg.max_len,
                                cfg.dim]);
                assert_eq!(a.outputs[1].name, "kv_new");
                assert_eq!(a.outputs[1].shape,
                           vec![cfg.layers, 2, a.batch, 1, cfg.dim]);
            }
        }
    }

    #[test]
    fn chunk_artifacts_have_prefix_bucket_and_kv_input() {
        let Some(m) = manifest() else { return };
        let arts = m.artifacts("tiny", Precision::Fp16).unwrap();
        let chunks: Vec<_> =
            arts.iter().filter(|a| a.phase == "chunk").collect();
        if chunks.is_empty() {
            eprintln!("skipping: pre-chunk artifacts (rebuild)");
            return;
        }
        let cfg = &m.model("tiny").unwrap().config;
        for a in chunks {
            assert!(a.prefix > 0 && a.prefix <= cfg.max_len, "{}", a.name);
            assert!(a.seq > 0, "{}", a.name);
            assert_eq!(a.inputs[0].name, "tokens");
            assert_eq!(a.inputs[0].shape, vec![a.batch, a.seq]);
            assert_eq!(a.inputs[1].name, "starts");
            assert_eq!(a.inputs[2].name, "kv");
            assert_eq!(a.inputs[2].shape,
                       vec![cfg.layers, 2, a.batch, a.prefix, cfg.dim]);
            assert_eq!(a.outputs[0].shape,
                       vec![a.batch, a.seq, cfg.vocab]);
            assert_eq!(a.outputs[1].name, "kv_new");
            assert_eq!(a.outputs[1].shape,
                       vec![cfg.layers, 2, a.batch, a.seq, cfg.dim]);
        }
    }

    #[test]
    fn hlo_files_exist() {
        let Some(m) = manifest() else { return };
        for (_, e) in &m.models {
            for a in &e.artifacts {
                assert!(m.hlo_path(a).exists(), "{}", a.file);
            }
        }
    }
}
