//! Synthetic text corpora with per-domain statistics.
//!
//! Each generator is seeded and deterministic. The domains deliberately
//! differ in identifier pools, punctuation density and line structure so
//! their *token and activation statistics* differ — which is all the
//! calibration-sensitivity experiment (paper Table 3) depends on.

use crate::util::rng::Rng;

/// One synthetic text domain, with its own identifier pools,
/// punctuation density, and line structure (so its token statistics are
/// distinguishable from the others').
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// Python-like function definitions (the paper's primary domain).
    CodePython,
    /// Java-like static methods (Table 2 multilingual setting).
    CodeJava,
    /// Go-like functions with tab indentation.
    CodeGo,
    /// C++-like functions over `std::vector`.
    CodeCpp,
    /// Pile-like running prose (Table 3 calibration-set study).
    PileProse,
    /// C4-like noisy web text with markup fragments.
    C4Web,
}

impl Domain {
    /// Every domain, code first (stable order used by the benches).
    pub fn all() -> [Domain; 6] {
        [Domain::CodePython, Domain::CodeJava, Domain::CodeGo,
         Domain::CodeCpp, Domain::PileProse, Domain::C4Web]
    }
    /// Just the four code domains (the Table 2 multilingual set).
    pub fn code_domains() -> [Domain; 4] {
        [Domain::CodePython, Domain::CodeJava, Domain::CodeGo,
         Domain::CodeCpp]
    }
    /// Short lowercase tag used in CLI flags and bench report keys.
    pub fn as_str(&self) -> &'static str {
        match self {
            Domain::CodePython => "python",
            Domain::CodeJava => "java",
            Domain::CodeGo => "go",
            Domain::CodeCpp => "cpp",
            Domain::PileProse => "pile",
            Domain::C4Web => "c4",
        }
    }
}

const IDENTS: [&str; 16] = [
    "total", "count", "value", "items", "result", "index", "buffer",
    "score", "node", "queue", "depth", "width", "cache", "state", "left",
    "right",
];
const VERBS: [&str; 10] = [
    "compute", "merge", "filter", "update", "scan", "reduce", "split",
    "encode", "decode", "sort",
];
const NOUNS: [&str; 12] = [
    "model", "array", "string", "number", "window", "matrix", "graph",
    "stream", "record", "table", "vector", "batch",
];
const PROSE_WORDS: [&str; 20] = [
    "the", "of", "and", "research", "system", "language", "data", "over",
    "many", "results", "shows", "large", "field", "method", "first",
    "between", "known", "century", "theory", "work",
];

/// Generate one document of roughly `target_chars` characters.
pub fn document(domain: Domain, rng: &mut Rng, target_chars: usize)
    -> String {
    let mut s = String::new();
    while s.len() < target_chars {
        match domain {
            Domain::CodePython => {
                let f = VERBS[rng.below(VERBS.len())];
                let a = IDENTS[rng.below(IDENTS.len())];
                let b = IDENTS[rng.below(IDENTS.len())];
                s.push_str(&format!(
                    "def {f}_{a}({a}, {b}):\n    {b} = {a} + \
                     {n}\n    return {b} * {a}\n\n",
                    n = rng.below(100)
                ));
            }
            Domain::CodeJava => {
                let f = VERBS[rng.below(VERBS.len())];
                let a = IDENTS[rng.below(IDENTS.len())];
                s.push_str(&format!(
                    "public static int {f}{A}(int {a}) {{\n    int x = \
                     {a} * {n};\n    return x + {a};\n}}\n\n",
                    A = capitalize(a),
                    n = rng.below(100)
                ));
            }
            Domain::CodeGo => {
                let f = VERBS[rng.below(VERBS.len())];
                let a = IDENTS[rng.below(IDENTS.len())];
                s.push_str(&format!(
                    "func {f}{A}({a} int) int {{\n\tif {a} > {n} {{\n\t\t\
                     return {a}\n\t}}\n\treturn {a} * 2\n}}\n\n",
                    A = capitalize(a),
                    n = rng.below(100)
                ));
            }
            Domain::CodeCpp => {
                let f = VERBS[rng.below(VERBS.len())];
                let a = IDENTS[rng.below(IDENTS.len())];
                s.push_str(&format!(
                    "int {f}_{a}(std::vector<int>& {a}) {{\n    int acc = \
                     {n};\n    for (auto v : {a}) acc += v;\n    return \
                     acc;\n}}\n\n",
                    n = rng.below(100)
                ));
            }
            Domain::PileProse => {
                for _ in 0..12 {
                    s.push_str(PROSE_WORDS[rng.below(PROSE_WORDS.len())]);
                    s.push(' ');
                }
                s.pop();
                s.push_str(". ");
            }
            Domain::C4Web => {
                let n = NOUNS[rng.below(NOUNS.len())];
                let v = VERBS[rng.below(VERBS.len())];
                s.push_str(&format!(
                    "Click here to {v} your {n}! Best {n} deals — \
                     {m}% off. <a href=\"/{n}/{v}\">{n}</a> | ",
                    m = 5 + rng.below(90)
                ));
            }
        }
    }
    s.truncate(target_chars);
    s
}

/// A corpus: `docs` documents of `chars` characters each.
pub fn corpus(domain: Domain, seed: u64, docs: usize, chars: usize)
    -> Vec<String> {
    let mut rng = Rng::new(seed ^ domain_tag(domain));
    (0..docs).map(|_| document(domain, &mut rng, chars)).collect()
}

/// Combined training text for the tokenizer (all domains, balanced).
pub fn tokenizer_training_text(seed: u64, chars_per_domain: usize)
    -> String {
    let mut out = String::new();
    for d in Domain::all() {
        let mut rng = Rng::new(seed ^ domain_tag(d));
        out.push_str(&document(d, &mut rng, chars_per_domain));
        out.push('\n');
    }
    out
}

fn domain_tag(d: Domain) -> u64 {
    match d {
        Domain::CodePython => 0x1001,
        Domain::CodeJava => 0x1002,
        Domain::CodeGo => 0x1003,
        Domain::CodeCpp => 0x1004,
        Domain::PileProse => 0x2001,
        Domain::C4Web => 0x3001,
    }
}

fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(corpus(Domain::CodePython, 1, 3, 200),
                   corpus(Domain::CodePython, 1, 3, 200));
        assert_ne!(corpus(Domain::CodePython, 1, 1, 200),
                   corpus(Domain::CodePython, 2, 1, 200));
    }

    #[test]
    fn domains_differ() {
        let py = document(Domain::CodePython, &mut Rng::new(0), 300);
        let go = document(Domain::CodeGo, &mut Rng::new(0), 300);
        let pr = document(Domain::PileProse, &mut Rng::new(0), 300);
        assert!(py.contains("def "));
        assert!(go.contains("func "));
        assert!(!pr.contains("return"));
        assert_ne!(py, go);
    }

    #[test]
    fn sizes_respected() {
        for d in Domain::all() {
            let c = corpus(d, 0, 4, 150);
            assert_eq!(c.len(), 4);
            assert!(c.iter().all(|s| s.len() == 150));
        }
    }
}
