//! Synthetic datasets and workload traces (DESIGN.md §5 substitutions for
//! HumanEval / Pile / C4 / the paper's online traffic).
//!
//! * [`corpus`] — six generated text domains with distinct token/channel
//!   statistics: four code languages (Python/Java/Go/C++ for the Table 2
//!   multilingual setting), pile-like prose and c4-like web text (the
//!   Table 3 calibration-set study).
//! * [`tasks`] — a fixed 164-prompt task set mirroring HumanEval's size
//!   and code-description style (calibration + pass@1-proxy evaluation).
//! * [`trace`] — Poisson-arrival synthetic traffic and a deterministic
//!   replayed "online" trace (Fig. 7a/7b workloads).

pub mod corpus;
pub mod tasks;
pub mod trace;
