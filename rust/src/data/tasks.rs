//! The evaluation task set: 164 fixed code-description prompts (mirroring
//! HumanEval's 164 problem descriptions), per code domain. Used both as
//! the paper's preferred calibration set and as the pass@1-proxy eval set.

use crate::util::rng::Rng;

use super::corpus::Domain;

/// Number of tasks per domain — matches HumanEval's 164 problems.
pub const NUM_TASKS: usize = 164;

/// One evaluation task: a prompt the model completes greedily.
#[derive(Debug, Clone)]
pub struct Task {
    /// Task index within its domain's set, `0..NUM_TASKS`.
    pub id: usize,
    /// The code domain the prompt asks for.
    pub domain: Domain,
    /// The comment-style task description the model completes.
    pub prompt: String,
}

const TOPICS: [&str; 12] = [
    "reverse a string", "sum a list of integers", "find the maximum",
    "check for palindromes", "merge two sorted arrays",
    "count vowels in a word", "compute a factorial",
    "filter even numbers", "flatten a nested list",
    "deduplicate elements", "binary search a value",
    "rotate an array left",
];

/// The fixed task set for a domain (deterministic; ids 0..164).
pub fn task_set(domain: Domain, seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed ^ 0x7a5c);
    (0..NUM_TASKS)
        .map(|id| {
            let topic = TOPICS[(id + rng.below(3)) % TOPICS.len()];
            let lang = domain.as_str();
            let prompt = format!(
                "// task {id:03}\n// Write a {lang} function to {topic}.\n\
                 // It should handle empty input and large values.\n"
            );
            Task { id, domain, prompt }
        })
        .collect()
}

/// Tokenized prompts for a task set, capped to `max_tokens` each.
pub fn tokenized_prompts(tasks: &[Task], tok: &crate::tokenizer::Tokenizer,
                         vocab: usize, max_tokens: usize) -> Vec<Vec<u32>> {
    tasks
        .iter()
        .map(|t| {
            let mut ids = tok.encode_for_model(&t.prompt, vocab);
            ids.truncate(max_tokens);
            if ids.is_empty() {
                ids.push(1);
            }
            ids
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_164_tasks_like_humaneval() {
        let t = task_set(Domain::CodePython, 0);
        assert_eq!(t.len(), NUM_TASKS);
        assert_eq!(t[0].id, 0);
        assert!(t[10].prompt.contains("python"));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = task_set(Domain::CodeGo, 5);
        let b = task_set(Domain::CodeGo, 5);
        assert_eq!(a[33].prompt, b[33].prompt);
    }

    #[test]
    fn tokenization_capped() {
        let tok = crate::tokenizer::Tokenizer::train(
            "def f(): return 1\n", 280);
        let tasks = task_set(Domain::CodePython, 0);
        let prompts = tokenized_prompts(&tasks[..8], &tok, 256, 16);
        assert_eq!(prompts.len(), 8);
        assert!(prompts.iter().all(|p| p.len() <= 16 && !p.is_empty()));
        assert!(prompts.iter().flatten().all(|&t| t < 256));
    }
}
