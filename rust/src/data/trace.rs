//! Workload traces: Poisson-arrival synthetic traffic (paper §3.3 "use
//! Poisson process to synthesize the request arrival times") and a
//! deterministic heavy-tailed "online replay" trace standing in for the
//! paper's recorded production traffic (Fig. 7b).

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Requested generation length in tokens.
    pub output_tokens: usize,
}

/// Poisson arrivals at `rate_per_s`, fixed prompt/output lengths
/// (the Fig. 7a grid sweeps these lengths).
pub fn poisson(seed: u64, n: usize, rate_per_s: f64, prompt_tokens: usize,
               output_tokens: usize) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate_per_s);
            TraceRequest { at_s: t, prompt_tokens, output_tokens }
        })
        .collect()
}

/// "Online replay": bursty arrivals (exponential bursts with pauses),
/// log-normal-ish prompt lengths, geometric output lengths — the shape of
/// interactive coding traffic.
pub fn online_replay(seed: u64, n: usize, mean_rate_per_s: f64,
                     max_prompt: usize, max_output: usize)
    -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed ^ 0x0417_11e5);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        // burst of 1-4 requests then a pause
        let burst = 1 + rng.below(4);
        for _ in 0..burst.min(n - out.len()) {
            t += rng.exponential(mean_rate_per_s * 4.0);
            let prompt = (2.0f64.powf(2.0 + 3.0 * rng.f64())) as usize;
            let output = 1 + (-(rng.f64().max(1e-9)).ln() * 8.0) as usize;
            out.push(TraceRequest {
                at_s: t,
                prompt_tokens: prompt.clamp(2, max_prompt),
                output_tokens: output.clamp(1, max_output),
            });
        }
        t += rng.exponential(mean_rate_per_s / 2.0);
    }
    out
}

/// Materialize token ids for a trace request from a token corpus stream.
pub fn prompt_tokens(rng: &mut Rng, len: usize, vocab: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(vocab.saturating_sub(1)).max(1) as u32)
        .collect()
}

/// Shared-prefix workload: `n` prompts that all start with the same
/// `prefix_len` tokens (a system prompt / few-shot template) followed by
/// a per-request random suffix — the traffic shape prefix caching is
/// built for. Deterministic in `seed`.
pub fn shared_prefix_prompts(seed: u64, n: usize, prefix_len: usize,
                             suffix_len: usize, vocab: usize)
    -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed ^ 0x5aed_c0de);
    let prefix = prompt_tokens(&mut rng, prefix_len, vocab);
    (0..n)
        .map(|_| {
            let mut p = prefix.clone();
            p.extend(prompt_tokens(&mut rng, suffix_len, vocab));
            p
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_right() {
        let tr = poisson(0, 4000, 10.0, 8, 8);
        let span = tr.last().unwrap().at_s;
        let rate = tr.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
        // arrivals are sorted
        assert!(tr.windows(2).all(|w| w[0].at_s <= w[1].at_s));
    }

    #[test]
    fn replay_bounded_and_deterministic() {
        let a = online_replay(7, 100, 5.0, 64, 32);
        let b = online_replay(7, 100, 5.0, 64, 32);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|r| r.prompt_tokens <= 64
            && r.output_tokens <= 32 && r.output_tokens >= 1));
        assert_eq!(a[50].prompt_tokens, b[50].prompt_tokens);
    }

    #[test]
    fn shared_prefix_shape() {
        let a = shared_prefix_prompts(3, 8, 24, 6, 512);
        let b = shared_prefix_prompts(3, 8, 24, 6, 512);
        assert_eq!(a, b); // deterministic
        assert_eq!(a.len(), 8);
        for p in &a {
            assert_eq!(p.len(), 30);
            assert_eq!(p[..24], a[0][..24]); // common prefix
            assert!(p.iter().all(|&t| t >= 1 && (t as usize) < 512));
        }
        // suffixes differ across requests
        assert_ne!(a[0][24..], a[1][24..]);
        // different seed, different prefix
        let c = shared_prefix_prompts(4, 2, 24, 6, 512);
        assert_ne!(c[0][..24], a[0][..24]);
    }

    #[test]
    fn replay_lengths_vary() {
        let tr = online_replay(1, 200, 5.0, 128, 32);
        let lens: std::collections::HashSet<usize> =
            tr.iter().map(|r| r.prompt_tokens).collect();
        assert!(lens.len() > 5, "prompt lengths too uniform");
    }
}
