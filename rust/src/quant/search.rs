//! The paper's **global** alpha grid search (§2.2, §3.4.2).
//!
//! A single smoothing strength `alpha` is chosen for the whole model by
//! minimizing the *entire model's* quantization loss over a grid on [0, 1]
//! (default step 0.05). This is the key methodological difference from
//! AWQ's per-layer search: the objective sums every linear's loss in the
//! original activation frame, so no layer-by-layer error accumulates, and
//! cached calibration activations make each grid point cheap (no forward
//! passes during the search).

use std::time::Instant;

use crate::config::{ModelConfig, QuantConfig};
use crate::model::store::WeightStore;
use crate::model::LAYER_LINEARS;
use crate::reffwd::Site;
use crate::util::threadpool::parallel_map;

use super::calib::CalibData;
use super::loss::{linear_loss, site_of};
use super::rtn;
use super::smooth::{smoothing_factors, unit_weight_absmax};

#[derive(Debug, Clone)]
pub struct SearchResult {
    pub alpha: f32,
    pub loss: f64,
    /// (alpha, whole-model loss) for every grid point.
    pub grid: Vec<(f32, f64)>,
    pub evals: usize,
    pub elapsed_s: f64,
}

/// Whole-model quantization loss if smoothed with `alpha` then group-wise
/// RTN-quantized. Loss is evaluated in the original activation frame.
pub fn loss_at_alpha(cfg: &ModelConfig, w: &WeightStore, calib: &CalibData,
                     group_size: usize, alpha: f32) -> f64 {
    // parallel over (layer, linear)
    let jobs: Vec<(usize, &'static str)> = (0..cfg.layers)
        .flat_map(|l| LAYER_LINEARS.iter().map(move |&lin| (l, lin)))
        .collect();
    let losses = parallel_map(jobs.len(), |i| {
        let (layer, lin) = jobs[i];
        let site: Site = site_of(lin);
        let stats = calib.stats(layer, site);
        let wmax = unit_weight_absmax(w, layer, site);
        let s = smoothing_factors(&stats.absmax, &wmax, alpha);
        let name = format!("layers.{layer}.{lin}");
        let orig = w.f32(&name);
        // scaled = diag(s) W ; eff = diag(s)^-1 dequant(quant(scaled))
        let mut scaled = orig.clone();
        scaled.scale_rows(&s);
        let mut eff = rtn::fake_quant(&scaled, group_size);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        eff.scale_rows(&inv);
        let rows = stats.rows.shape[0].max(1) as f64;
        linear_loss(&stats.rows, orig, &eff) / rows
    });
    losses.iter().sum()
}

/// Like [`loss_at_alpha`], but with the smoothing factors driven by one
/// calibration set (`calib_s`) and the loss evaluated on another
/// (`calib_eval`) — the Table-3 calibration-sensitivity readout: how much
/// does quantizing against the wrong activation distribution cost on the
/// distribution that matters?
pub fn loss_at_alpha_cross(cfg: &ModelConfig, w: &WeightStore,
                           calib_s: &CalibData, calib_eval: &CalibData,
                           group_size: usize, alpha: f32) -> f64 {
    let jobs: Vec<(usize, &'static str)> = (0..cfg.layers)
        .flat_map(|l| LAYER_LINEARS.iter().map(move |&lin| (l, lin)))
        .collect();
    let losses = parallel_map(jobs.len(), |i| {
        let (layer, lin) = jobs[i];
        let site: Site = site_of(lin);
        let stats_s = calib_s.stats(layer, site);
        let stats_e = calib_eval.stats(layer, site);
        let wmax = unit_weight_absmax(w, layer, site);
        let s = smoothing_factors(&stats_s.absmax, &wmax, alpha);
        let name = format!("layers.{layer}.{lin}");
        let orig = w.f32(&name);
        let mut scaled = orig.clone();
        scaled.scale_rows(&s);
        let mut eff = rtn::fake_quant(&scaled, group_size);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        eff.scale_rows(&inv);
        let rows = stats_e.rows.shape[0].max(1) as f64;
        linear_loss(&stats_e.rows, orig, &eff) / rows
    });
    losses.iter().sum()
}

/// Grid search over alpha in [0, 1] with `qcfg.alpha_step`.
pub fn search_alpha(cfg: &ModelConfig, w: &WeightStore, calib: &CalibData,
                    qcfg: &QuantConfig) -> SearchResult {
    let t0 = Instant::now();
    let mut grid = Vec::new();
    let steps = (1.0 / qcfg.alpha_step).round() as usize;
    for i in 0..=steps {
        let alpha = (i as f64 * qcfg.alpha_step).min(1.0) as f32;
        let loss = loss_at_alpha(cfg, w, calib, qcfg.group_size, alpha);
        grid.push((alpha, loss));
    }
    let (alpha, loss) = grid
        .iter()
        .cloned()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    SearchResult {
        alpha,
        loss,
        evals: grid.len(),
        grid,
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::calib;

    fn setup() -> (ModelConfig, WeightStore, CalibData) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..10).map(|t| (i * 101 + t * 17) % 512).collect())
            .collect();
        let calib = calib::collect(&cfg, &w, &prompts, 24, 0);
        (cfg, w, calib)
    }

    #[test]
    fn search_covers_grid_and_picks_min() {
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig { alpha_step: 0.25, ..Default::default() };
        let r = search_alpha(&cfg, &w, &calib, &qcfg);
        assert_eq!(r.grid.len(), 5); // 0, .25, .5, .75, 1
        let min = r.grid.iter().map(|g| g.1).fold(f64::INFINITY, f64::min);
        assert_eq!(r.loss, min);
        assert!(r.grid.iter().any(|g| g.0 == r.alpha));
    }

    #[test]
    fn smoothing_beats_no_smoothing_with_outliers() {
        // the paper's central claim: with activation outliers present, a
        // smoothed quantization has lower loss than plain RTN. RTN is not
        // a grid point of Eq. 6 (s == 1 needs alpha such that a^x = w^(1-x)
        // per channel), so compare against the direct un-smoothed loss.
        let (cfg, w, calib) = setup();
        let rtn_loss: f64 = {
            use crate::model::LAYER_LINEARS;
            use crate::quant::loss::{linear_loss, site_of};
            let mut total = 0.0;
            for layer in 0..cfg.layers {
                for lin in LAYER_LINEARS {
                    let name = format!("layers.{layer}.{lin}");
                    let stats = calib.stats(layer, site_of(lin));
                    let eff =
                        crate::quant::rtn::fake_quant(w.f32(&name), 128);
                    let rows = stats.rows.shape[0].max(1) as f64;
                    total +=
                        linear_loss(&stats.rows, w.f32(&name), &eff) / rows;
                }
            }
            total
        };
        let qcfg = QuantConfig { alpha_step: 0.05, ..Default::default() };
        let r = search_alpha(&cfg, &w, &calib, &qcfg);
        assert!(
            r.loss < rtn_loss,
            "searched smoothing loss {} !< RTN loss {rtn_loss}",
            r.loss
        );
    }

    #[test]
    fn loss_curve_is_finite_everywhere() {
        let (cfg, w, calib) = setup();
        for alpha in [0.0f32, 0.5, 1.0] {
            let l = loss_at_alpha(&cfg, &w, &calib, 128, alpha);
            assert!(l.is_finite() && l >= 0.0, "alpha {alpha}: {l}");
        }
    }
}
