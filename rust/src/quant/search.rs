//! The paper's **global** alpha grid search (§2.2, §3.4.2).
//!
//! A single smoothing strength `alpha` is chosen for the whole model by
//! minimizing the *entire model's* quantization loss over a grid on [0, 1]
//! (default step 0.05). This is the key methodological difference from
//! AWQ's per-layer search: the objective sums every linear's loss in the
//! original activation frame, so no layer-by-layer error accumulates, and
//! cached calibration activations make each grid point cheap (no forward
//! passes during the search).
//!
//! The grid loop is allocation-free on weights: [`AlphaSearchCtx`]
//! precomputes each smoothing unit's weight absmax and calibration lookups
//! **once**, and every grid point evaluates the fused
//! [`quant_loss`](super::loss::quant_loss) — no weight-store or weight
//! clone per evaluation (the pre-fusion implementation cloned and
//! fake-quantized every decoder weight at all ~21 grid points).

use std::time::Instant;

use crate::config::{ModelConfig, QuantConfig};
use crate::model::store::WeightStore;
use crate::reffwd::Site;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_map;

use super::calib::CalibData;
use super::loss::quant_loss;
use super::smooth::{smoothing_factors, unit_weight_absmax};

/// Outcome of the global-alpha grid search (paper Eq. 6/7).
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Winning smoothing strength.
    pub alpha: f32,
    /// Whole-model loss at the winner.
    pub loss: f64,
    /// (alpha, whole-model loss) for every grid point.
    pub grid: Vec<(f32, f64)>,
    /// Loss evaluations performed.
    pub evals: usize,
    /// Wall-clock search time.
    pub elapsed_s: f64,
}

/// Per-smoothing-unit state shared by every alpha grid point: the unit's
/// activation absmax (driving Eq. 6), the combined consumer weight absmax,
/// and borrowed views of the consumer weights + eval activation rows.
struct UnitCtx<'a> {
    layer: usize,
    act_absmax: &'a [f32],
    wmax: Vec<f32>,
    /// (weight, eval rows, eval row count) per consumer linear.
    consumers: Vec<(&'a Tensor, &'a Tensor, f64)>,
}

/// Precomputed whole-model search context. Building it performs the
/// per-(layer, site) stats lookups and `unit_weight_absmax` reductions
/// exactly once; [`AlphaSearchCtx::loss_at`] then evaluates a grid point
/// with zero full-weight-tensor clones.
pub struct AlphaSearchCtx<'a> {
    group_size: usize,
    units: Vec<UnitCtx<'a>>,
}

impl<'a> AlphaSearchCtx<'a> {
    /// Context with factors and evaluation driven by the same calib set.
    pub fn new(cfg: &ModelConfig, w: &'a WeightStore,
               calib: &'a CalibData, group_size: usize) -> Self {
        Self::cross(cfg, w, calib, calib, group_size)
    }

    /// Smoothing factors driven by `calib_s`, loss evaluated on
    /// `calib_eval` (the Table-3 calibration-sensitivity split).
    pub fn cross(cfg: &ModelConfig, w: &'a WeightStore,
                 calib_s: &'a CalibData, calib_eval: &'a CalibData,
                 group_size: usize) -> Self {
        let mut units = Vec::with_capacity(cfg.layers * 4);
        for layer in 0..cfg.layers {
            for site in Site::all() {
                let stats_s = calib_s.stats(layer, site);
                let stats_e = calib_eval.stats(layer, site);
                let wmax = unit_weight_absmax(w, layer, site);
                let consumers = site
                    .consumers()
                    .iter()
                    .map(|lin| {
                        let orig = w.f32(&format!("layers.{layer}.{lin}"));
                        let rows = stats_e.rows.shape[0].max(1) as f64;
                        (orig, &stats_e.rows, rows)
                    })
                    .collect();
                units.push(UnitCtx {
                    layer,
                    act_absmax: &stats_s.absmax,
                    wmax,
                    consumers,
                });
            }
        }
        AlphaSearchCtx { group_size, units }
    }

    /// Per-unit losses at one alpha, parallel across units. Each unit
    /// computes its Eq.-6 factors once and streams the fused loss over its
    /// consumer linears — no tensor is cloned or materialized.
    fn unit_losses_at(&self, alpha: f32) -> Vec<f64> {
        parallel_map(self.units.len(), |u| {
            let unit = &self.units[u];
            let s = smoothing_factors(unit.act_absmax, &unit.wmax, alpha);
            let mut total = 0.0;
            for &(orig, rows, nrows) in &unit.consumers {
                total +=
                    quant_loss(rows, orig, Some(&s), self.group_size, 1.0)
                        / nrows;
            }
            total
        })
    }

    /// Whole-model quantization loss at one alpha (original frame).
    pub fn loss_at(&self, alpha: f32) -> f64 {
        self.unit_losses_at(alpha).iter().sum()
    }

    /// Loss at one alpha, broken down per decoder layer.
    pub fn per_layer_losses_at(&self, layers: usize, alpha: f32)
        -> Vec<f64> {
        let per_unit = self.unit_losses_at(alpha);
        let mut out = vec![0.0; layers];
        for (unit, l) in self.units.iter().zip(&per_unit) {
            out[unit.layer] += l;
        }
        out
    }
}

/// Whole-model quantization loss if smoothed with `alpha` then group-wise
/// RTN-quantized. Loss is evaluated in the original activation frame.
/// (One-shot wrapper; grid loops should build an [`AlphaSearchCtx`] once.)
pub fn loss_at_alpha(cfg: &ModelConfig, w: &WeightStore, calib: &CalibData,
                     group_size: usize, alpha: f32) -> f64 {
    AlphaSearchCtx::new(cfg, w, calib, group_size).loss_at(alpha)
}

/// Like [`loss_at_alpha`], but with the smoothing factors driven by one
/// calibration set (`calib_s`) and the loss evaluated on another
/// (`calib_eval`) — the Table-3 calibration-sensitivity readout: how much
/// does quantizing against the wrong activation distribution cost on the
/// distribution that matters?
pub fn loss_at_alpha_cross(cfg: &ModelConfig, w: &WeightStore,
                           calib_s: &CalibData, calib_eval: &CalibData,
                           group_size: usize, alpha: f32) -> f64 {
    AlphaSearchCtx::cross(cfg, w, calib_s, calib_eval, group_size)
        .loss_at(alpha)
}

/// Grid search over alpha in [0, 1] with `qcfg.alpha_step`, reusing a
/// prebuilt context across all grid points.
pub fn search_alpha_with(ctx: &AlphaSearchCtx, qcfg: &QuantConfig)
    -> SearchResult {
    // sqlint: allow(determinism) wall-clock timing for pipeline reporting; results unaffected
    let t0 = Instant::now();
    let mut grid = Vec::new();
    let steps = (1.0 / qcfg.alpha_step).round() as usize;
    for i in 0..=steps {
        let alpha = (i as f64 * qcfg.alpha_step).min(1.0) as f32;
        grid.push((alpha, ctx.loss_at(alpha)));
    }
    let (alpha, loss) = grid
        .iter()
        .cloned()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    SearchResult {
        alpha,
        loss,
        evals: grid.len(),
        grid,
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

/// Grid search over alpha in [0, 1] with `qcfg.alpha_step`.
pub fn search_alpha(cfg: &ModelConfig, w: &WeightStore, calib: &CalibData,
                    qcfg: &QuantConfig) -> SearchResult {
    let ctx = AlphaSearchCtx::new(cfg, w, calib, qcfg.group_size);
    search_alpha_with(&ctx, qcfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::calib;

    fn setup() -> (ModelConfig, WeightStore, CalibData) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..10).map(|t| (i * 101 + t * 17) % 512).collect())
            .collect();
        let calib = calib::collect(&cfg, &w, &prompts, 24, 0);
        (cfg, w, calib)
    }

    #[test]
    fn search_covers_grid_and_picks_min() {
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig { alpha_step: 0.25, ..Default::default() };
        let r = search_alpha(&cfg, &w, &calib, &qcfg);
        assert_eq!(r.grid.len(), 5); // 0, .25, .5, .75, 1
        let min = r.grid.iter().map(|g| g.1).fold(f64::INFINITY, f64::min);
        assert_eq!(r.loss, min);
        assert!(r.grid.iter().any(|g| g.0 == r.alpha));
    }

    #[test]
    fn smoothing_beats_no_smoothing_with_outliers() {
        // the paper's central claim: with activation outliers present, a
        // smoothed quantization has lower loss than plain RTN. RTN is not
        // a grid point of Eq. 6 (s == 1 needs alpha such that a^x = w^(1-x)
        // per channel), so compare against the direct un-smoothed loss.
        let (cfg, w, calib) = setup();
        let rtn_loss: f64 = {
            use crate::model::LAYER_LINEARS;
            use crate::quant::loss::{linear_loss, site_of};
            let mut total = 0.0;
            for layer in 0..cfg.layers {
                for lin in LAYER_LINEARS {
                    let name = format!("layers.{layer}.{lin}");
                    let stats = calib.stats(layer, site_of(lin));
                    let eff =
                        crate::quant::rtn::fake_quant(w.f32(&name), 128);
                    let rows = stats.rows.shape[0].max(1) as f64;
                    total +=
                        linear_loss(&stats.rows, w.f32(&name), &eff) / rows;
                }
            }
            total
        };
        let qcfg = QuantConfig { alpha_step: 0.05, ..Default::default() };
        let r = search_alpha(&cfg, &w, &calib, &qcfg);
        assert!(
            r.loss < rtn_loss,
            "searched smoothing loss {} !< RTN loss {rtn_loss}",
            r.loss
        );
    }

    #[test]
    fn loss_curve_is_finite_everywhere() {
        let (cfg, w, calib) = setup();
        for alpha in [0.0f32, 0.5, 1.0] {
            let l = loss_at_alpha(&cfg, &w, &calib, 128, alpha);
            assert!(l.is_finite() && l >= 0.0, "alpha {alpha}: {l}");
        }
    }

    #[test]
    fn ctx_matches_independent_unfused_reference() {
        // validate the hoisted-precompute + fused-loss path against an
        // independently-coded reference: the pre-fusion per-linear
        // pipeline (clone, scale, fake-quant, unscale, linear_loss)
        use crate::model::LAYER_LINEARS;
        use crate::quant::loss::{linear_loss, site_of};
        use crate::quant::rtn;
        let (cfg, w, calib) = setup();
        let ctx = AlphaSearchCtx::new(&cfg, &w, &calib, 128);
        for alpha in [0.0f32, 0.4, 1.0] {
            let mut unfused = 0.0f64;
            for layer in 0..cfg.layers {
                for lin in LAYER_LINEARS {
                    let site = site_of(lin);
                    let stats = calib.stats(layer, site);
                    let wmax = unit_weight_absmax(&w, layer, site);
                    let s =
                        smoothing_factors(&stats.absmax, &wmax, alpha);
                    let name = format!("layers.{layer}.{lin}");
                    let mut scaled = w.f32(&name).clone();
                    scaled.scale_rows(&s);
                    let mut eff = rtn::fake_quant(&scaled, 128);
                    let inv: Vec<f32> =
                        s.iter().map(|&v| 1.0 / v).collect();
                    eff.scale_rows(&inv);
                    let rows = stats.rows.shape[0].max(1) as f64;
                    unfused +=
                        linear_loss(&stats.rows, w.f32(&name), &eff)
                            / rows;
                }
            }
            // per-linear terms are bit-identical; only the f64 summation
            // grouping differs (per-unit partials), hence assert_close
            crate::util::prop::assert_close(
                ctx.loss_at(alpha),
                unfused,
                1e-12,
                "fused ctx vs unfused reference",
            );
            let per_layer = ctx.per_layer_losses_at(cfg.layers, alpha);
            assert_eq!(per_layer.len(), cfg.layers);
            let sum: f64 = per_layer.iter().sum();
            crate::util::prop::assert_close(
                sum,
                unfused,
                1e-12,
                "per-layer sum == total",
            );
        }
    }
}
