//! Fused host-side W4A16 kernel: `x[M,K] @ dequant(Wq)[K,N]` straight from
//! packed nibbles — the CPU twin of the Pallas dequant-GEMM (see
//! `python/compile/kernels/w4a16.py`).
//!
//! The kernel never materializes the dequantized `[K, N]` f32 weight.
//! Writing the group-wise affine dequantization
//! `w[k,j] = (q[k,j] - z[g,j]) * s[g,j]` into the GEMM and factoring per
//! group `g`:
//!
//! ```text
//! out[i,j] = Σ_g s[g,j] · ( Σ_{k∈g} x[i,k]·q[k,j]  −  z[g,j]·Σ_{k∈g} x[i,k] )
//! ```
//!
//! so the inner loop accumulates raw nibble values against `x` and the
//! scale/zero correction is applied once per (group, output block) — one
//! multiply-add per weight element plus O(N/g) overhead, with weight
//! traffic 4× smaller than the f32 GEMM. Work is tiled over
//! `MB×JB` output blocks (stack-resident accumulators, no allocation in
//! the hot loop) and threaded across blocks with `parallel_for`.

use crate::tensor::{Tensor, U8Tensor};
use crate::util::threadpool::{parallel_for, SendPtr};

use super::rtn::QuantizedLinear;

/// Output rows per tile (bounds the stack accumulator).
const MB: usize = 16;
/// Output columns per tile.
const JB: usize = 64;

/// `x[M,K] @ dequant(q)[K,N] -> [M,N]` without dequantizing `q`.
///
/// Agrees with `x.matmul(&q.dequantize())` up to f32 reassociation
/// (~1e-6 relative; the property suite checks 1e-4).
pub fn matmul_w4a16(x: &Tensor, q: &QuantizedLinear) -> Tensor {
    matmul_w4a16_parts(x, &q.packed, &q.scales, &q.zeros, q.group_size)
}

/// [`matmul_w4a16`] on a deploy-store triple (packed / scales / zeros held
/// as separate named tensors, as uploaded to the device runtime).
pub fn matmul_w4a16_parts(x: &Tensor, packed: &U8Tensor, scales: &Tensor,
                          zeros: &Tensor, group_size: usize) -> Tensor {
    let (m, k) = x.dims2();
    assert_eq!(packed.shape.len(), 2, "packed must be rank-2");
    let kp = packed.shape[0] * 2;
    let n = packed.shape[1];
    assert_eq!(k, kp, "matmul_w4a16 inner dims {k} vs {kp}");
    assert_eq!(k % group_size, 0, "K={k} % group={group_size}");
    let groups = k / group_size;
    assert_eq!(scales.shape, vec![groups, n], "scales shape");
    assert_eq!(zeros.shape, vec![groups, n], "zeros shape");

    let mut out = Tensor::zeros(&[m, n]);
    // SAFETY: each task owns the disjoint output block
    // [i0, i0+rb) x [j0, j0+jw).
    let op = SendPtr::new(out.data.as_mut_ptr());
    let nbi = m.div_ceil(MB);
    let nbj = n.div_ceil(JB);
    let xd = &x.data;
    let pd = &packed.data;
    let sd = &scales.data;
    let zd = &zeros.data;
    parallel_for(nbi * nbj, |t| {
        let i0 = (t / nbj) * MB;
        let j0 = (t % nbj) * JB;
        let rb = MB.min(m - i0);
        let jw = JB.min(n - j0);
        // stack-resident tile state: the hot loop performs no allocation
        let mut acc = [[0.0f32; JB]; MB];
        let mut nib = [0.0f32; JB];
        let mut xsum = [0.0f32; MB];
        for g in 0..groups {
            for r in 0..rb {
                acc[r][..jw].fill(0.0);
                xsum[r] = 0.0;
            }
            for kk in g * group_size..(g + 1) * group_size {
                // unpack this input-channel row's nibbles once per tile
                let boff = (kk >> 1) * n + j0;
                let brow = &pd[boff..boff + jw];
                let shift = 4 * ((kk & 1) as u32);
                for j in 0..jw {
                    nib[j] = ((brow[j] >> shift) & 0xF) as f32;
                }
                for r in 0..rb {
                    let xv = xd[(i0 + r) * k + kk];
                    if xv == 0.0 {
                        continue;
                    }
                    xsum[r] += xv;
                    let arow = &mut acc[r];
                    for j in 0..jw {
                        arow[j] += xv * nib[j];
                    }
                }
            }
            // fold in this group's scale/zero correction
            let srow = &sd[g * n + j0..g * n + j0 + jw];
            let zrow = &zd[g * n + j0..g * n + j0 + jw];
            for r in 0..rb {
                let orow = unsafe {
                    std::slice::from_raw_parts_mut(
                        op.get().add((i0 + r) * n + j0),
                        jw,
                    )
                };
                let xs = xsum[r];
                let arow = &acc[r];
                for j in 0..jw {
                    orow[j] += srow[j] * (arow[j] - xs * zrow[j]);
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn;
    use crate::util::{prop, rng::Rng};

    fn rand_t(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::from_vec(
            shape,
            (0..shape.iter().product::<usize>())
                .map(|_| rng.normal() * scale)
                .collect(),
        )
    }

    #[test]
    fn exact_on_grid_weights() {
        // weights already on the quant grid dequantize exactly, so the
        // fused kernel must match the dense matmul to f32 rounding
        let mut rng = Rng::new(7);
        let (k, n, g) = (64usize, 48usize, 32usize);
        let mut data: Vec<f32> = (0..k * n)
            .map(|_| (rng.below(16) as f32 - 7.0) * 0.25)
            .collect();
        // pin both grid extremes into every (group, column) so the
        // quantizer reconstructs exactly the 0.25-step grid
        for grow in 0..k / g {
            for j in 0..n {
                data[(grow * g) * n + j] = -7.0 * 0.25;
                data[(grow * g + 1) * n + j] = 8.0 * 0.25;
            }
        }
        let w = Tensor::from_vec(&[k, n], data);
        let q = rtn::quantize(&w, g);
        let x = rand_t(&mut rng, &[3, k], 1.0);
        let got = matmul_w4a16(&x, &q);
        let want = x.matmul(&w);
        prop::assert_allclose(&got.data, &want.data, 1e-4, 1e-4, "grid");
    }

    #[test]
    fn decode_shape_single_row() {
        let mut rng = Rng::new(11);
        let (k, n) = (256usize, 96usize);
        let w = rand_t(&mut rng, &[k, n], 0.7);
        let q = rtn::quantize(&w, 128);
        let x = rand_t(&mut rng, &[1, k], 1.0);
        let got = matmul_w4a16(&x, &q);
        assert_eq!(got.shape, vec![1, n]);
        let want = x.matmul(&q.dequantize());
        prop::assert_allclose(&got.data, &want.data, 1e-3, 1e-4, "m=1");
    }

    #[test]
    fn zero_activations_give_zero_output() {
        let mut rng = Rng::new(3);
        let w = rand_t(&mut rng, &[64, 40], 1.0);
        let q = rtn::quantize(&w, 64);
        let x = Tensor::zeros(&[5, 64]);
        let got = matmul_w4a16(&x, &q);
        assert!(got.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn odd_group_size_supported() {
        // the kernel indexes nibbles directly, so groups need not be
        // byte-aligned (the quantizer's scalar fallback produces these)
        let mut rng = Rng::new(19);
        let (k, n) = (30usize, 24usize); // group 15, k even
        let w = rand_t(&mut rng, &[k, n], 0.5);
        let q = rtn::quantize(&w, 15);
        let x = rand_t(&mut rng, &[4, k], 1.0);
        let got = matmul_w4a16(&x, &q);
        let want = x.matmul(&q.dequantize());
        prop::assert_allclose(&got.data, &want.data, 1e-3, 1e-4, "odd g");
    }

    #[test]
    fn parts_view_matches_owned() {
        let mut rng = Rng::new(23);
        let w = rand_t(&mut rng, &[128, 70], 1.0);
        let q = rtn::quantize(&w, 64);
        let x = rand_t(&mut rng, &[6, 128], 1.0);
        let a = matmul_w4a16(&x, &q);
        let b = matmul_w4a16_parts(&x, &q.packed, &q.scales, &q.zeros,
                                   q.group_size);
        assert_eq!(a.data, b.data);
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let mut rng = Rng::new(1);
        let w = rand_t(&mut rng, &[64, 8], 1.0);
        let q = rtn::quantize(&w, 64);
        let x = rand_t(&mut rng, &[2, 32], 1.0);
        matmul_w4a16(&x, &q);
    }
}
