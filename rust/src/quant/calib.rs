//! Calibration statistics: per-channel activation absmax/absmean and a
//! reservoir of retained activation rows per smoothing site, collected by
//! running the reference forward pass over a calibration corpus.
//!
//! The paper calibrates on the 164 HumanEval problem descriptions; the
//! corresponding synthetic calibration sets live in `crate::data`.

use std::collections::HashMap;

use crate::config::ModelConfig;
use crate::model::store::WeightStore;
use crate::reffwd::{ActHook, RefModel, Site};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-(layer, site) channel statistics + retained rows.
#[derive(Debug, Clone)]
pub struct SiteStats {
    /// Input channels at this site.
    pub channels: usize,
    /// max_t |X[t, j]| over all calibration tokens.
    pub absmax: Vec<f32>,
    /// mean_t |X[t, j]|.
    pub absmean: Vec<f32>,
    /// Reservoir-sampled activation rows `[R, C]` for loss evaluation.
    pub rows: Tensor,
    /// Calibration tokens folded into these statistics.
    pub tokens_seen: usize,
}

/// Calibration data for a whole model.
#[derive(Debug, Clone)]
pub struct CalibData {
    /// Statistics per (decoder layer, activation site).
    pub sites: HashMap<(usize, Site), SiteStats>,
    /// Total calibration tokens processed.
    pub tokens: usize,
}

impl CalibData {
    /// Statistics for one (layer, site); panics if uncollected.
    pub fn stats(&self, layer: usize, site: Site) -> &SiteStats {
        self.sites
            .get(&(layer, site))
            .unwrap_or_else(|| panic!("no calib for layer {layer} {site:?}"))
    }
}

struct Collector {
    max_rows: usize,
    rng: Rng,
    acc: HashMap<(usize, Site), Acc>,
}

struct Acc {
    absmax: Vec<f32>,
    abssum: Vec<f64>,
    rows: Vec<Vec<f32>>,
    seen: usize,
}

impl ActHook for Collector {
    fn record(&mut self, layer: usize, site: Site, rows: &Tensor) {
        let (t, c) = rows.dims2();
        let acc = self.acc.entry((layer, site)).or_insert_with(|| Acc {
            absmax: vec![0.0; c],
            abssum: vec![0.0; c],
            rows: Vec::new(),
            seen: 0,
        });
        for i in 0..t {
            let row = rows.row(i);
            for j in 0..c {
                let a = row[j].abs();
                acc.absmax[j] = acc.absmax[j].max(a);
                acc.abssum[j] += a as f64;
            }
            // reservoir sampling: uniform over all rows seen
            acc.seen += 1;
            if acc.rows.len() < self.max_rows {
                acc.rows.push(row.to_vec());
            } else {
                let r = self.rng.below(acc.seen);
                if r < self.max_rows {
                    acc.rows[r] = row.to_vec();
                }
            }
        }
    }
}

/// Run the model over `prompts` and collect calibration data, retaining at
/// most `max_rows` activation rows per site.
pub fn collect(cfg: &ModelConfig, w: &WeightStore, prompts: &[Vec<u32>],
               max_rows: usize, seed: u64) -> CalibData {
    let model = RefModel::new(cfg, w);
    let mut col = Collector {
        max_rows,
        rng: Rng::new(seed),
        acc: HashMap::new(),
    };
    let mut tokens = 0;
    for p in prompts {
        if p.is_empty() {
            continue;
        }
        let capped = &p[..p.len().min(cfg.max_len)];
        tokens += capped.len();
        model.prefill(capped, &mut col);
    }
    let sites = col
        .acc
        .into_iter()
        .map(|(k, a)| {
            let c = a.absmax.len();
            let n = a.seen.max(1) as f64;
            let r = a.rows.len();
            let mut flat = Vec::with_capacity(r * c);
            for row in &a.rows {
                flat.extend_from_slice(row);
            }
            (
                k,
                SiteStats {
                    channels: c,
                    absmax: a.absmax,
                    absmean: a.abssum.iter().map(|&s| (s / n) as f32)
                        .collect(),
                    rows: Tensor::from_vec(&[r, c], flat),
                    tokens_seen: a.seen,
                },
            )
        })
        .collect();
    CalibData { sites, tokens }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};

    fn setup() -> (ModelConfig, WeightStore) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::default());
        (cfg, w)
    }

    #[test]
    fn collects_every_site() {
        let (cfg, w) = setup();
        let prompts = vec![vec![1, 2, 3, 4], vec![9, 8, 7]];
        let calib = collect(&cfg, &w, &prompts, 16, 0);
        assert_eq!(calib.tokens, 7);
        for layer in 0..cfg.layers {
            for site in Site::all() {
                let s = calib.stats(layer, site);
                assert_eq!(s.tokens_seen, 7);
                assert_eq!(s.rows.shape, vec![7, s.channels]);
                // absmean <= absmax per channel
                for j in 0..s.channels {
                    assert!(s.absmean[j] <= s.absmax[j] + 1e-6);
                }
            }
        }
    }

    #[test]
    fn reservoir_caps_rows() {
        let (cfg, w) = setup();
        let prompts = vec![(0u32..60).map(|i| i % cfg.vocab as u32).collect()];
        let calib = collect(&cfg, &w, &prompts, 8, 1);
        let s = calib.stats(0, Site::AttnIn);
        assert_eq!(s.rows.shape[0], 8);
        assert_eq!(s.tokens_seen, 60);
    }

    #[test]
    fn channel_dims_match_sites() {
        let (cfg, w) = setup();
        let calib = collect(&cfg, &w, &[vec![1, 2, 3]], 8, 0);
        assert_eq!(calib.stats(0, Site::AttnIn).channels, cfg.dim);
        assert_eq!(calib.stats(0, Site::DownIn).channels, cfg.ffn);
    }

    #[test]
    fn outlier_channels_show_in_absmax() {
        let cfg = ModelConfig::tiny();
        let spec = InitSpec::with_outliers(0, 4, 60.0);
        let w = init_weights(&cfg, &spec);
        let calib = collect(&cfg, &w, &[vec![5, 10, 15, 20, 25]], 8, 0);
        let s = calib.stats(0, Site::AttnIn);
        let mut sorted = s.absmax.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[s.channels / 2];
        assert!(sorted[s.channels - 1] > 10.0 * median);
    }
}
