//! Group-wise asymmetric INT4 round-to-nearest quantization (paper Eq. 1).
//!
//! Numerics mirror `python/compile/kernels/ref.py` exactly (the pytest
//! suite is the oracle; `rust/tests/cross_numerics.rs` checks agreement
//! through the PJRT-executed kernel):
//!
//! ```text
//! delta = (max - min) / 15          (constant group: |c| / 15)
//! z     = round(-min / delta)       (f32, unclamped)
//! q     = clamp(round(w / delta) + z, 0, 15)
//! deq   = (q - z) * delta
//! ```
//!
//! The quantize hot loop is a row-blocked single pass, threaded over
//! quantization groups: each group task computes its per-column (min, max)
//! and grid, then quantizes two input-channel rows at a time straight into
//! packed bytes — no intermediate `q: Vec<u8>` of size K·N is ever
//! materialized (the pre-fusion implementation walked the weight
//! column-major, single-threaded, and allocated that buffer).

use crate::tensor::{Tensor, U8Tensor};
use crate::util::threadpool::{parallel_for, SendPtr};

use super::pack;

/// Largest INT4 code (the grid spans 0..=15).
pub const NIBBLE_MAX: f32 = 15.0;

/// The INT4 grid for one (already clipped) group range: `(delta, zero)`.
/// Single source of truth shared by the quantizer (both paths) and the
/// fused `loss::quant_loss` — their bit-for-bit agreement depends on this
/// being the only implementation of Eq. 1's grid.
#[inline]
pub fn int4_grid(lo: f32, hi: f32) -> (f32, f32) {
    let mut delta = (hi - lo) / NIBBLE_MAX;
    if delta == 0.0 {
        delta = hi.abs().max(1e-12) / NIBBLE_MAX;
    }
    (delta, (-lo / delta).round())
}

/// Quantized form of one `[K, N]` weight.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// Packed nibbles `u8[K/2, N]` (two consecutive input-channel rows per
    /// byte, low nibble first — see `crate::tensor` module docs).
    pub packed: U8Tensor,
    /// Per-group step `f32[K/g, N]`.
    pub scales: Tensor,
    /// Per-group zero point (integer-valued f32) `f32[K/g, N]`.
    pub zeros: Tensor,
    /// Input channels per quantization group.
    pub group_size: usize,
}

impl QuantizedLinear {
    /// Input-channel count K.
    pub fn k(&self) -> usize {
        self.packed.shape[0] * 2
    }
    /// Output-channel count N.
    pub fn n(&self) -> usize {
        self.packed.shape[1]
    }
    /// Dequantize back to a dense `[K, N]` tensor (fused unpack + affine,
    /// threaded over byte rows; no intermediate nibble buffer).
    pub fn dequantize(&self) -> Tensor {
        let (k, n) = (self.k(), self.n());
        let g = self.group_size;
        let mut out = Tensor::zeros(&[k, n]);
        // SAFETY: byte row i writes output rows 2i and 2i+1 only.
        let op = SendPtr::new(out.data.as_mut_ptr());
        let pd = &self.packed.data;
        let sd = &self.scales.data;
        let zd = &self.zeros.data;
        parallel_for(k / 2, |i| {
            let lo_row = unsafe {
                std::slice::from_raw_parts_mut(op.get().add(2 * i * n), n)
            };
            let hi_row = unsafe {
                std::slice::from_raw_parts_mut(
                    op.get().add((2 * i + 1) * n),
                    n,
                )
            };
            let brow = &pd[i * n..(i + 1) * n];
            let glo = (2 * i) / g;
            let ghi = (2 * i + 1) / g;
            for j in 0..n {
                let b = brow[j];
                lo_row[j] = ((b & 0xF) as f32 - zd[glo * n + j])
                    * sd[glo * n + j];
                hi_row[j] = ((b >> 4) as f32 - zd[ghi * n + j])
                    * sd[ghi * n + j];
            }
        });
        out
    }
}

/// Quantize `w: [K, N]` with groups of `group_size` consecutive input
/// channels. `clip_ratio < 1.0` shrinks each group's (min, max) range
/// toward zero before building the grid (AWQ-style clip search).
pub fn quantize_clipped(w: &Tensor, group_size: usize, clip_ratio: f32)
    -> QuantizedLinear {
    let (k, n) = w.dims2();
    assert_eq!(k % group_size, 0, "K={k} % group={group_size}");
    assert_eq!(k % 2, 0, "K={k} must be even to pack");
    if group_size % 2 != 0 {
        // Odd group sizes share packed bytes across group boundaries, so
        // the group-parallel packed writes below would race; keep the
        // simple scalar path for this cold case.
        return quantize_clipped_scalar(w, group_size, clip_ratio);
    }

    let groups = k / group_size;
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    let mut packed = vec![0u8; k / 2 * n];
    // SAFETY: group `grow` writes scales/zeros row `grow` and packed byte
    // rows [grow*g/2, (grow+1)*g/2) — disjoint across tasks (g is even).
    let sp = SendPtr::new(scales.as_mut_ptr());
    let zp = SendPtr::new(zeros.as_mut_ptr());
    let pp = SendPtr::new(packed.as_mut_ptr());
    parallel_for(groups, |grow| {
        let srow = unsafe {
            std::slice::from_raw_parts_mut(sp.get().add(grow * n), n)
        };
        let zrow = unsafe {
            std::slice::from_raw_parts_mut(zp.get().add(grow * n), n)
        };
        let prows = unsafe {
            std::slice::from_raw_parts_mut(
                pp.get().add(grow * group_size / 2 * n),
                group_size / 2 * n,
            )
        };
        quantize_group(w, grow, group_size, clip_ratio, srow, zrow, prows);
    });
    QuantizedLinear {
        packed: U8Tensor::from_vec(&[k / 2, n], packed),
        scales: Tensor::from_vec(&[groups, n], scales),
        zeros: Tensor::from_vec(&[groups, n], zeros),
        group_size,
    }
}

/// One group's fused pass: per-column (min, max) over the group's rows,
/// grid construction, then quantize two rows at a time into packed bytes.
fn quantize_group(w: &Tensor, grow: usize, group_size: usize,
                  clip_ratio: f32, srow: &mut [f32], zrow: &mut [f32],
                  prows: &mut [u8]) {
    let n = w.shape[1];
    let k0 = grow * group_size;
    // pass 1: per-column range, walking the group row-major
    let mut wmin = vec![f32::INFINITY; n];
    let mut wmax = vec![f32::NEG_INFINITY; n];
    for kk in k0..k0 + group_size {
        let row = &w.data[kk * n..(kk + 1) * n];
        for j in 0..n {
            let v = row[j];
            if v < wmin[j] {
                wmin[j] = v;
            }
            if v > wmax[j] {
                wmax[j] = v;
            }
        }
    }
    // pass 2: per-column grid
    for j in 0..n {
        let (delta, z) =
            int4_grid(wmin[j] * clip_ratio, wmax[j] * clip_ratio);
        srow[j] = delta;
        zrow[j] = z;
    }
    // pass 3: quantize + pack, two input-channel rows per output byte
    for pair in 0..group_size / 2 {
        let ka = k0 + 2 * pair;
        let ra = &w.data[ka * n..ka * n + n];
        let rb = &w.data[(ka + 1) * n..(ka + 1) * n + n];
        let out = &mut prows[pair * n..pair * n + n];
        for j in 0..n {
            let delta = srow[j];
            let z = zrow[j];
            let qa = ((ra[j] / delta).round() + z).clamp(0.0, NIBBLE_MAX)
                as u8;
            let qb = ((rb[j] / delta).round() + z).clamp(0.0, NIBBLE_MAX)
                as u8;
            out[j] = qa | (qb << 4);
        }
    }
}

/// Scalar fallback (odd group sizes only): the original column-major walk
/// with an explicit nibble buffer.
fn quantize_clipped_scalar(w: &Tensor, group_size: usize, clip_ratio: f32)
    -> QuantizedLinear {
    let (k, n) = w.dims2();
    let groups = k / group_size;
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    let mut q = vec![0u8; k * n];
    for grow in 0..groups {
        for j in 0..n {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for kk in grow * group_size..(grow + 1) * group_size {
                let v = w.data[kk * n + j];
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            wmin *= clip_ratio;
            wmax *= clip_ratio;
            let (delta, z) = int4_grid(wmin, wmax);
            scales[grow * n + j] = delta;
            zeros[grow * n + j] = z;
            for kk in grow * group_size..(grow + 1) * group_size {
                let v = w.data[kk * n + j];
                let qq = ((v / delta).round() + z).clamp(0.0, NIBBLE_MAX);
                q[kk * n + j] = qq as u8;
            }
        }
    }
    QuantizedLinear {
        packed: pack::pack_nibbles(&q, k, n),
        scales: Tensor::from_vec(&[groups, n], scales),
        zeros: Tensor::from_vec(&[groups, n], zeros),
        group_size,
    }
}

/// Plain RTN (no clipping).
pub fn quantize(w: &Tensor, group_size: usize) -> QuantizedLinear {
    quantize_clipped(w, group_size, 1.0)
}

/// Quantize-dequantize round trip ("the weight the model will see").
pub fn fake_quant(w: &Tensor, group_size: usize) -> Tensor {
    quantize(w, group_size).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn rand_w(rng: &mut Rng, k: usize, n: usize, scale: f32) -> Tensor {
        Tensor::from_vec(
            &[k, n],
            (0..k * n).map(|_| rng.normal() * scale).collect(),
        )
    }

    #[test]
    fn error_bounded_by_1p5_delta() {
        prop::check("rtn error bound", 20, |rng| {
            let k = 128 * (1 + rng.below(2));
            let n = 1 + rng.below(16);
            let loc = (rng.f32() - 0.5) * 10.0;
            let scale = 0.01 + rng.f32() * 5.0;
            let w = {
                let mut t = rand_w(rng, k, n, scale);
                for v in &mut t.data {
                    *v += loc;
                }
                t
            };
            let ql = quantize(&w, 128);
            let deq = ql.dequantize();
            for kk in 0..k {
                for j in 0..n {
                    let s = ql.scales.data[(kk / 128) * n + j];
                    let err = (deq.data[kk * n + j] - w.data[kk * n + j])
                        .abs();
                    assert!(
                        err <= 1.5 * s + 1e-5,
                        "err {err} > 1.5*{s}"
                    );
                }
            }
        });
    }

    #[test]
    fn fused_matches_scalar_path() {
        // the threaded row-blocked pass and the scalar column-major walk
        // must agree bit-for-bit (same grid, same nibbles, same packing)
        prop::check("fused == scalar rtn", 10, |rng| {
            let g = 2 * (1 + rng.below(4)); // even group
            let k = g * (1 + rng.below(5));
            let n = 1 + rng.below(20);
            let clip = if rng.below(2) == 0 { 1.0 } else { 0.9 };
            let w = rand_w(rng, k, n, 0.5 + rng.f32());
            let a = quantize_clipped(&w, g, clip);
            let b = quantize_clipped_scalar(&w, g, clip);
            assert_eq!(a.packed.data, b.packed.data);
            assert_eq!(a.scales.data, b.scales.data);
            assert_eq!(a.zeros.data, b.zeros.data);
        });
    }

    #[test]
    fn grid_points_roundtrip_exactly() {
        // values already on a quant grid survive exactly
        let mut rng = Rng::new(5);
        let scale = 0.125f32;
        let data: Vec<f32> = (0..128 * 4)
            .map(|_| (rng.below(16) as f32 - 5.0) * scale)
            .collect();
        let w = Tensor::from_vec(&[128, 4], data.clone());
        let deq = fake_quant(&w, 128);
        prop::assert_allclose(&deq.data, &data, 1e-6, 1e-6, "grid");
    }

    #[test]
    fn constant_group_exact() {
        for c in [0.731f32, -2.5, 0.0] {
            let w = Tensor::from_vec(&[128, 2], vec![c; 256]);
            let deq = fake_quant(&w, 128);
            prop::assert_allclose(&deq.data, &w.data, 1e-6, 1e-6, "const");
        }
    }

    #[test]
    fn positive_only_group_ok() {
        // the case a clamped zero point would destroy
        let mut rng = Rng::new(9);
        let w = Tensor::from_vec(
            &[64, 4],
            (0..256).map(|_| 5.0 + 0.001 * rng.normal()).collect(),
        );
        let ql = quantize(&w, 32);
        let deq = ql.dequantize();
        let maxerr = prop::max_abs_diff(&deq.data, &w.data);
        assert!(maxerr < 0.001, "maxerr {maxerr}");
    }

    #[test]
    fn clipping_shrinks_scale() {
        let mut rng = Rng::new(3);
        let w = rand_w(&mut rng, 128, 8, 1.0);
        let a = quantize_clipped(&w, 128, 1.0);
        let b = quantize_clipped(&w, 128, 0.8);
        for (sa, sb) in a.scales.data.iter().zip(&b.scales.data) {
            assert!(sb < sa);
        }
    }

    #[test]
    fn shapes() {
        let mut rng = Rng::new(1);
        let w = rand_w(&mut rng, 256, 12, 1.0);
        let ql = quantize(&w, 64);
        assert_eq!(ql.packed.shape, vec![128, 12]);
        assert_eq!(ql.scales.shape, vec![4, 12]);
        assert_eq!(ql.zeros.shape, vec![4, 12]);
        assert_eq!((ql.k(), ql.n()), (256, 12));
        // zero points integer-valued
        assert!(ql.zeros.data.iter().all(|z| *z == z.round()));
    }
}
