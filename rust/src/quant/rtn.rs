//! Group-wise asymmetric INT4 round-to-nearest quantization (paper Eq. 1).
//!
//! Numerics mirror `python/compile/kernels/ref.py` exactly (the pytest
//! suite is the oracle; `rust/tests/cross_numerics.rs` checks agreement
//! through the PJRT-executed kernel):
//!
//! ```text
//! delta = (max - min) / 15          (constant group: |c| / 15)
//! z     = round(-min / delta)       (f32, unclamped)
//! q     = clamp(round(w / delta) + z, 0, 15)
//! deq   = (q - z) * delta
//! ```

use crate::tensor::{Tensor, U8Tensor};

use super::pack;

pub const NIBBLE_MAX: f32 = 15.0;

/// Quantized form of one `[K, N]` weight.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// Packed nibbles `u8[K/2, N]`.
    pub packed: U8Tensor,
    /// Per-group step `f32[K/g, N]`.
    pub scales: Tensor,
    /// Per-group zero point (integer-valued f32) `f32[K/g, N]`.
    pub zeros: Tensor,
    pub group_size: usize,
}

impl QuantizedLinear {
    pub fn k(&self) -> usize {
        self.packed.shape[0] * 2
    }
    pub fn n(&self) -> usize {
        self.packed.shape[1]
    }
    /// Dequantize back to a dense `[K, N]` tensor.
    pub fn dequantize(&self) -> Tensor {
        let (k, n) = (self.k(), self.n());
        let q = pack::unpack_nibbles(&self.packed);
        let g = self.group_size;
        let mut out = vec![0.0f32; k * n];
        for kk in 0..k {
            let grow = kk / g;
            for j in 0..n {
                let s = self.scales.data[grow * n + j];
                let z = self.zeros.data[grow * n + j];
                out[kk * n + j] = (q[kk * n + j] as f32 - z) * s;
            }
        }
        Tensor::from_vec(&[k, n], out)
    }
}

/// Quantize `w: [K, N]` with groups of `group_size` consecutive input
/// channels. `clip_ratio < 1.0` shrinks each group's (min, max) range
/// toward zero before building the grid (AWQ-style clip search).
pub fn quantize_clipped(w: &Tensor, group_size: usize, clip_ratio: f32)
    -> QuantizedLinear {
    let (k, n) = w.dims2();
    assert_eq!(k % group_size, 0, "K={k} % group={group_size}");
    let groups = k / group_size;
    let mut scales = vec![0.0f32; groups * n];
    let mut zeros = vec![0.0f32; groups * n];
    let mut q = vec![0u8; k * n];
    for grow in 0..groups {
        for j in 0..n {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for kk in grow * group_size..(grow + 1) * group_size {
                let v = w.data[kk * n + j];
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            wmin *= clip_ratio;
            wmax *= clip_ratio;
            let mut delta = (wmax - wmin) / NIBBLE_MAX;
            if delta == 0.0 {
                delta = wmax.abs().max(1e-12) / NIBBLE_MAX;
            }
            let z = (-wmin / delta).round();
            scales[grow * n + j] = delta;
            zeros[grow * n + j] = z;
            for kk in grow * group_size..(grow + 1) * group_size {
                let v = w.data[kk * n + j];
                let qq = ((v / delta).round() + z).clamp(0.0, NIBBLE_MAX);
                q[kk * n + j] = qq as u8;
            }
        }
    }
    QuantizedLinear {
        packed: pack::pack_nibbles(&q, k, n),
        scales: Tensor::from_vec(&[groups, n], scales),
        zeros: Tensor::from_vec(&[groups, n], zeros),
        group_size,
    }
}

/// Plain RTN (no clipping).
pub fn quantize(w: &Tensor, group_size: usize) -> QuantizedLinear {
    quantize_clipped(w, group_size, 1.0)
}

/// Quantize-dequantize round trip ("the weight the model will see").
pub fn fake_quant(w: &Tensor, group_size: usize) -> Tensor {
    quantize(w, group_size).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn rand_w(rng: &mut Rng, k: usize, n: usize, scale: f32) -> Tensor {
        Tensor::from_vec(
            &[k, n],
            (0..k * n).map(|_| rng.normal() * scale).collect(),
        )
    }

    #[test]
    fn error_bounded_by_1p5_delta() {
        prop::check("rtn error bound", 20, |rng| {
            let k = 128 * (1 + rng.below(2));
            let n = 1 + rng.below(16);
            let loc = (rng.f32() - 0.5) * 10.0;
            let scale = 0.01 + rng.f32() * 5.0;
            let w = {
                let mut t = rand_w(rng, k, n, scale);
                for v in &mut t.data {
                    *v += loc;
                }
                t
            };
            let ql = quantize(&w, 128);
            let deq = ql.dequantize();
            for kk in 0..k {
                for j in 0..n {
                    let s = ql.scales.data[(kk / 128) * n + j];
                    let err = (deq.data[kk * n + j] - w.data[kk * n + j])
                        .abs();
                    assert!(
                        err <= 1.5 * s + 1e-5,
                        "err {err} > 1.5*{s}"
                    );
                }
            }
        });
    }

    #[test]
    fn grid_points_roundtrip_exactly() {
        // values already on a quant grid survive exactly
        let mut rng = Rng::new(5);
        let scale = 0.125f32;
        let data: Vec<f32> = (0..128 * 4)
            .map(|_| (rng.below(16) as f32 - 5.0) * scale)
            .collect();
        let w = Tensor::from_vec(&[128, 4], data.clone());
        let deq = fake_quant(&w, 128);
        prop::assert_allclose(&deq.data, &data, 1e-6, 1e-6, "grid");
    }

    #[test]
    fn constant_group_exact() {
        for c in [0.731f32, -2.5, 0.0] {
            let w = Tensor::from_vec(&[128, 2], vec![c; 256]);
            let deq = fake_quant(&w, 128);
            prop::assert_allclose(&deq.data, &w.data, 1e-6, 1e-6, "const");
        }
    }

    #[test]
    fn positive_only_group_ok() {
        // the case a clamped zero point would destroy
        let mut rng = Rng::new(9);
        let w = Tensor::from_vec(
            &[64, 4],
            (0..256).map(|_| 5.0 + 0.001 * rng.normal()).collect(),
        );
        let ql = quantize(&w, 32);
        let deq = ql.dequantize();
        let maxerr = prop::max_abs_diff(&deq.data, &w.data);
        assert!(maxerr < 0.001, "maxerr {maxerr}");
    }

    #[test]
    fn clipping_shrinks_scale() {
        let mut rng = Rng::new(3);
        let w = rand_w(&mut rng, 128, 8, 1.0);
        let a = quantize_clipped(&w, 128, 1.0);
        let b = quantize_clipped(&w, 128, 0.8);
        for (sa, sb) in a.scales.data.iter().zip(&b.scales.data) {
            assert!(sb < sa);
        }
    }

    #[test]
    fn shapes() {
        let mut rng = Rng::new(1);
        let w = rand_w(&mut rng, 256, 12, 1.0);
        let ql = quantize(&w, 64);
        assert_eq!(ql.packed.shape, vec![128, 12]);
        assert_eq!(ql.scales.shape, vec![4, 12]);
        assert_eq!(ql.zeros.shape, vec![4, 12]);
        assert_eq!((ql.k(), ql.n()), (256, 12));
        // zero points integer-valued
        assert!(ql.zeros.data.iter().all(|z| *z == z.round()));
    }
}
