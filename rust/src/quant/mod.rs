//! The paper's quantization library.
//!
//! * [`rtn`] — group-wise asymmetric INT4 round-to-nearest quantization
//!   (the paper's Eq. 1, with the zero point kept in f32 — see
//!   `python/compile/kernels/ref.py` for the shared convention).
//! * [`pack`] — two-nibbles-per-byte packing used by the W4A16 kernel.
//! * [`smooth`] — SmoothQuant+ per-channel smoothing (Eq. 5/6) with
//!   mathematically-equivalent fusion into the producing layer.
//! * [`calib`] — calibration statistics (per-channel activation absmax /
//!   absmean + retained activation rows) collected from the reference
//!   forward pass.
//! * [`loss`] — the quantization loss `E = ||XW - X Ŵ||²` (Eq. 4).
//! * [`search`] — the paper's *global* grid search for the smoothing
//!   strength alpha (step 0.05).
//! * [`awq`] — the AWQ baseline: per-layer activation-aware scaling with
//!   mean-based importance and clip search (local objective; exhibits the
//!   error-accumulation the paper criticises).
//! * [`pipeline`] — end-to-end "method" entry points mapping
//!   [`crate::config::QuantMethod`] to a quantized model.

pub mod awq;
pub mod calib;
pub mod loss;
pub mod pack;
pub mod pipeline;
pub mod rtn;
pub mod search;
pub mod smooth;
