//! The paper's quantization library.
//!
//! * [`rtn`] — group-wise asymmetric INT4 round-to-nearest quantization
//!   (the paper's Eq. 1, with the zero point kept in f32 — see
//!   `python/compile/kernels/ref.py` for the shared convention). The
//!   quantize pass is row-blocked, threaded over groups, and packs
//!   nibbles in the same pass (no K·N intermediate).
//! * [`pack`] — two-nibbles-per-byte packing used by the W4A16 kernel:
//!   byte `(k2, j)` holds input-channel rows `2*k2` (low nibble) and
//!   `2*k2 + 1` (high nibble) of output column `j`.
//! * [`kernel`] — the fused host-side W4A16 dequant-matmul:
//!   `x @ dequant(Wq)` computed straight from packed nibbles with the
//!   group scale/zero folded in per tile, never materializing the f32
//!   weight. Mirrors the Pallas kernel the PJRT runtime executes; the
//!   host serving path (`reffwd` in packed mode) runs through it.
//! * [`smooth`] — SmoothQuant+ per-channel smoothing (Eq. 5/6) with
//!   mathematically-equivalent fusion into the producing layer.
//! * [`calib`] — calibration statistics (per-channel activation absmax /
//!   absmean + retained activation rows) collected from the reference
//!   forward pass.
//! * [`loss`] — the quantization loss `E = ||XW - X Ŵ||²` (Eq. 4),
//!   including the fused `quant_loss` that evaluates a smoothed+clipped
//!   candidate with zero weight clones (the search/AWQ grid hot path).
//! * [`search`] — the paper's *global* grid search for the smoothing
//!   strength alpha (step 0.05). `AlphaSearchCtx` hoists the
//!   per-(layer, site) weight absmax and calibration lookups out of the
//!   grid loop so all ~21 grid points share one precompute.
//! * [`awq`] — the AWQ baseline: per-layer activation-aware scaling with
//!   mean-based importance and clip search (local objective; exhibits the
//!   error-accumulation the paper criticises).
//! * [`pipeline`] — end-to-end "method" entry points mapping
//!   [`crate::config::QuantMethod`] to a quantized model.

pub mod awq;
pub mod calib;
pub mod kernel;
pub mod loss;
pub mod pack;
pub mod pipeline;
pub mod rtn;
pub mod search;
pub mod smooth;
