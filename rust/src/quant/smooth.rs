//! SmoothQuant+ per-channel smoothing (paper Eq. 5/6).
//!
//! `s_j = max|X_j|^alpha / max|W_j|^(1-alpha)` per input channel j of each
//! smoothing unit; activations are divided by `s` by folding `diag(s)^-1`
//! into the *producer* (so the model stays mathematically equivalent), and
//! consumer weights are multiplied row-wise by `s`:
//!
//! | unit (site)   | producer fold (÷ s)           | consumers (rows × s) |
//! |---------------|-------------------------------|----------------------|
//! | `AttnIn`      | `attn_norm` gain              | wq, wk, wv           |
//! | `OIn`         | `wv` output columns           | wo                   |
//! | `MlpIn`       | `mlp_norm` gain               | w_gate, w_up         |
//! | `DownIn`      | `w_up` output columns         | w_down               |
//!
//! (`OIn` works because attention mixes tokens, not channels: scaling v's
//! channels by 1/s scales the attention output's channels by 1/s. `DownIn`
//! works because SwiGLU is elementwise.) This covers all 7 linears of the
//! decoder layer — the residual-stream fusion of the paper's Figure 5.

use crate::config::ModelConfig;
use crate::model::store::WeightStore;
use crate::reffwd::Site;
use crate::tensor::Tensor;

use super::calib::CalibData;

const S_MIN: f32 = 1e-5;
const S_MAX: f32 = 1e5;

/// Eq. 6: per-channel smoothing factors from activation and weight absmax.
pub fn smoothing_factors(act_absmax: &[f32], w_absmax: &[f32], alpha: f32)
    -> Vec<f32> {
    assert_eq!(act_absmax.len(), w_absmax.len());
    act_absmax
        .iter()
        .zip(w_absmax)
        .map(|(&a, &w)| {
            let a = a.max(S_MIN);
            let w = w.max(S_MIN);
            (a.powf(alpha) / w.powf(1.0 - alpha)).clamp(S_MIN, S_MAX)
        })
        .collect()
}

/// Combined per-input-channel |W| max over a unit's consumer linears.
pub fn unit_weight_absmax(store: &WeightStore, layer: usize, site: Site)
    -> Vec<f32> {
    let mut out: Option<Vec<f32>> = None;
    for lin in site.consumers() {
        let w = store.f32(&format!("layers.{layer}.{lin}"));
        let rm = w.row_absmax();
        out = Some(match out {
            None => rm,
            Some(mut acc) => {
                for (a, b) in acc.iter_mut().zip(&rm) {
                    *a = a.max(*b);
                }
                acc
            }
        });
    }
    out.expect("site has consumers")
}

/// The smoothing factors chosen for each (layer, site).
#[derive(Debug, Clone, Default)]
pub struct SmoothingReport {
    /// Per-channel factors applied at each (layer, site).
    pub factors: Vec<((usize, Site), Vec<f32>)>,
    /// Smoothing strength the factors were computed with.
    pub alpha: f32,
}

/// Smooth the model in place with strength `alpha`, folding the inverse
/// factors into producers per the table above. Returns the factors used.
pub fn smooth_model(store: &mut WeightStore, cfg: &ModelConfig,
                    calib: &CalibData, alpha: f32) -> SmoothingReport {
    let mut report = SmoothingReport { factors: vec![], alpha };
    for layer in 0..cfg.layers {
        for site in Site::all() {
            let stats = calib.stats(layer, site);
            let wmax = unit_weight_absmax(store, layer, site);
            let s = smoothing_factors(&stats.absmax, &wmax, alpha);
            apply_unit(store, layer, site, &s);
            report.factors.push(((layer, site), s));
        }
    }
    report
}

/// Apply one unit's factors: producer ÷ s, consumer rows × s.
pub fn apply_unit(store: &mut WeightStore, layer: usize, site: Site,
                  s: &[f32]) {
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    let lp = |n: &str| format!("layers.{layer}.{n}");
    match site {
        Site::AttnIn => {
            scale_vec(store.f32_mut(&lp("attn_norm")), &inv);
            for lin in ["wq", "wk", "wv"] {
                store.f32_mut(&lp(lin)).scale_rows(s);
            }
        }
        Site::OIn => {
            store.f32_mut(&lp("wv")).scale_cols(&inv);
            store.f32_mut(&lp("wo")).scale_rows(s);
        }
        Site::MlpIn => {
            scale_vec(store.f32_mut(&lp("mlp_norm")), &inv);
            for lin in ["w_gate", "w_up"] {
                store.f32_mut(&lp(lin)).scale_rows(s);
            }
        }
        Site::DownIn => {
            store.f32_mut(&lp("w_up")).scale_cols(&inv);
            store.f32_mut(&lp("w_down")).scale_rows(s);
        }
    }
}

fn scale_vec(t: &mut Tensor, s: &[f32]) {
    assert_eq!(t.data.len(), s.len());
    for (x, &f) in t.data.iter_mut().zip(s) {
        *x *= f;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::calib;
    use crate::reffwd::{NoHook, RefModel};
    use crate::util::prop;

    fn setup() -> (ModelConfig, WeightStore, CalibData) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|i| (0..12).map(|t| (i * 37 + t * 13) % 512).collect())
                  .collect();
        let calib = calib::collect(&cfg, &w, &prompts, 32, 0);
        (cfg, w, calib)
    }

    #[test]
    fn factors_formula() {
        let s = smoothing_factors(&[4.0, 16.0], &[1.0, 4.0], 0.5);
        prop::assert_allclose(&s, &[2.0, 2.0], 1e-5, 1e-6, "eq6");
        // alpha = 1: pure activation max
        let s = smoothing_factors(&[4.0, 9.0], &[7.0, 7.0], 1.0);
        prop::assert_allclose(&s, &[4.0, 9.0], 1e-5, 1e-6, "alpha=1");
        // alpha = 0: pure inverse weight max
        let s = smoothing_factors(&[4.0, 9.0], &[2.0, 8.0], 0.0);
        prop::assert_allclose(&s, &[0.5, 0.125], 1e-5, 1e-6, "alpha=0");
    }

    #[test]
    fn smoothing_is_mathematically_equivalent() {
        // The paper's core equivalence claim (Eq. 5): smoothed model ==
        // original model, for any alpha.
        let (cfg, w, calib) = setup();
        let tokens = [3u32, 77, 205, 11, 460, 9];
        let (want, _) = RefModel::new(&cfg, &w).prefill(&tokens, &mut NoHook);
        for alpha in [0.0, 0.35, 0.5, 0.85, 1.0] {
            let mut sm = w.clone();
            smooth_model(&mut sm, &cfg, &calib, alpha);
            let (got, _) =
                RefModel::new(&cfg, &sm).prefill(&tokens, &mut NoHook);
            prop::assert_allclose(&got.data, &want.data, 2e-3, 2e-3,
                                  &format!("alpha {alpha}"));
        }
    }

    #[test]
    fn decode_also_equivalent() {
        let (cfg, w, calib) = setup();
        let mut sm = w.clone();
        smooth_model(&mut sm, &cfg, &calib, 0.5);
        let orig = RefModel::new(&cfg, &w);
        let smod = RefModel::new(&cfg, &sm);
        let (_, mut c1) = orig.prefill(&[1, 2, 3], &mut NoHook);
        let (_, mut c2) = smod.prefill(&[1, 2, 3], &mut NoHook);
        let a = orig.decode(42, &mut c1, &mut NoHook);
        let b = smod.decode(42, &mut c2, &mut NoHook);
        prop::assert_allclose(&a, &b, 2e-3, 2e-3, "decode equiv");
    }

    #[test]
    fn smoothing_flattens_activation_outliers() {
        // after smoothing with alpha=0.5, the smoothed model's activation
        // absmax spread (max / median) must shrink dramatically
        let (cfg, w, calib) = setup();
        let mut sm = w.clone();
        smooth_model(&mut sm, &cfg, &calib, 0.5);
        let prompts: Vec<Vec<u32>> = vec![(0..12).map(|t| t * 13 % 512)
            .collect()];
        let after = calib::collect(&cfg, &sm, &prompts, 8, 0);
        let spread = |c: &CalibData| {
            let s = c.stats(0, crate::reffwd::Site::AttnIn);
            let mut m = s.absmax.clone();
            m.sort_by(|a, b| a.partial_cmp(b).unwrap());
            m[s.channels - 1] / m[s.channels / 2].max(1e-9)
        };
        let before_spread = spread(&calib);
        let after_spread = spread(&after);
        assert!(
            after_spread < before_spread / 4.0,
            "spread before {before_spread} after {after_spread}"
        );
    }

    #[test]
    fn unit_weight_absmax_combines_consumers() {
        let (cfg, w, _) = setup();
        let m = unit_weight_absmax(&w, 0, Site::AttnIn);
        assert_eq!(m.len(), cfg.dim);
        let wq = w.f32("layers.0.wq").row_absmax();
        for j in 0..cfg.dim {
            assert!(m[j] >= wq[j]);
        }
    }
}
