//! Quantization loss (paper Eq. 4): `E = ||X W - X Ŵ||²_F`, evaluated on
//! retained calibration rows. Both W and Ŵ are expressed in the *original*
//! activation frame, so smoothed candidates are compared fairly:
//! `Ŵ_eff = diag(s)^-1 · dequant(quant(diag(s) · W))`.

use crate::config::ModelConfig;
use crate::model::store::WeightStore;
use crate::model::LAYER_LINEARS;
use crate::reffwd::Site;
use crate::tensor::Tensor;

use super::calib::CalibData;

/// `||X (W - W_eff)||²_F` for one linear.
pub fn linear_loss(x_rows: &Tensor, w: &Tensor, w_eff: &Tensor) -> f64 {
    let e = w.sub(w_eff);
    x_rows.matmul(&e).frob_sq()
}

/// The site whose activation feeds a given linear.
pub fn site_of(linear: &str) -> Site {
    match linear {
        "wq" | "wk" | "wv" => Site::AttnIn,
        "wo" => Site::OIn,
        "w_gate" | "w_up" => Site::MlpIn,
        "w_down" => Site::DownIn,
        _ => panic!("unknown linear {linear}"),
    }
}

/// Per-decoder-layer and total quantization loss of an effective model
/// (original-frame weights) against the original model. Normalized per
/// calibration row so sizes are comparable (the paper's Fig. 3 / Tab. 4
/// readout).
#[derive(Debug, Clone)]
pub struct ModelLoss {
    pub per_layer: Vec<f64>,
    pub total: f64,
}

pub fn model_quant_loss(cfg: &ModelConfig, orig: &WeightStore,
                        effective: &WeightStore, calib: &CalibData)
    -> ModelLoss {
    let mut per_layer = Vec::with_capacity(cfg.layers);
    for layer in 0..cfg.layers {
        let mut l = 0.0;
        for lin in LAYER_LINEARS {
            let name = format!("layers.{layer}.{lin}");
            let stats = calib.stats(layer, site_of(lin));
            let rows = stats.rows.shape[0].max(1) as f64;
            l += linear_loss(&stats.rows, orig.f32(&name),
                             effective.f32(&name)) / rows;
        }
        per_layer.push(l);
    }
    let total = per_layer.iter().sum();
    ModelLoss { per_layer, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::{calib, rtn};

    #[test]
    fn zero_for_identical_weights() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w = Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 1.]);
        assert_eq!(linear_loss(&x, &w, &w), 0.0);
    }

    #[test]
    fn positive_for_perturbed_weights() {
        let x = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        let mut w2 = w.clone();
        w2.data[0] += 0.1;
        assert!(linear_loss(&x, &w, &w2) > 0.0);
    }

    #[test]
    fn model_loss_runs_and_is_positive_under_rtn() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::default());
        let calib = calib::collect(&cfg, &w, &[vec![1, 2, 3, 4, 5]], 8, 0);
        let mut eff = w.clone();
        for layer in 0..cfg.layers {
            for lin in LAYER_LINEARS {
                let name = format!("layers.{layer}.{lin}");
                let fq = rtn::fake_quant(w.f32(&name), cfg.group_size);
                eff.set_f32(&name, fq);
            }
        }
        let ml = model_quant_loss(&cfg, &w, &eff, &calib);
        assert_eq!(ml.per_layer.len(), cfg.layers);
        assert!(ml.total > 0.0);
        assert!(ml.per_layer.iter().all(|&l| l >= 0.0));
        // identical model has zero loss
        let z = model_quant_loss(&cfg, &w, &w, &calib);
        assert_eq!(z.total, 0.0);
    }

    #[test]
    fn site_mapping_complete() {
        for lin in LAYER_LINEARS {
            let s = site_of(lin);
            assert!(s.consumers().contains(&lin));
        }
    }
}
