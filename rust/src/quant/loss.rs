//! Quantization loss (paper Eq. 4): `E = ||X W - X Ŵ||²_F`, evaluated on
//! retained calibration rows. Both W and Ŵ are expressed in the *original*
//! activation frame, so smoothed candidates are compared fairly:
//! `Ŵ_eff = diag(s)^-1 · dequant(quant(diag(s) · W))`.
//!
//! [`quant_loss`] is the fused form used on the search hot path: it
//! streams over quantization groups, building each group's grid and the
//! per-element error `w - deq/s` on the fly, and accumulates `X·(W−Ŵ)`
//! directly — no weight clone, no fake-quant round trip, no difference
//! tensor. It is bit-for-bit equal to the unfused
//! `clone → scale_rows → fake_quant → scale_rows(1/s) → linear_loss`
//! pipeline it replaced (the property suite asserts exact equality).

use crate::config::ModelConfig;
use crate::model::store::WeightStore;
use crate::model::LAYER_LINEARS;
use crate::reffwd::Site;
use crate::tensor::Tensor;

use super::calib::CalibData;
use super::rtn::{int4_grid, NIBBLE_MAX};

/// `||X (W - W_eff)||²_F` for one linear.
pub fn linear_loss(x_rows: &Tensor, w: &Tensor, w_eff: &Tensor) -> f64 {
    let e = w.sub(w_eff);
    x_rows.matmul(&e).frob_sq()
}

/// Fused quantization loss of `w: [K, N]` against activation rows
/// `x_rows: [R, K]`, optionally smoothed by per-input-channel factors `s`
/// and range-clipped by `clip_ratio` (1.0 = none):
///
/// `||X (W − diag(s)^-1 · dequant(quant_clipped(diag(s) · W)))||²_F`
///
/// Single-threaded by design — the callers (alpha grid, AWQ grid) already
/// parallelize across units/grid points, so the inner loop stays a clean
/// streaming pass: per column block, per group, (1) scaled min/max,
/// (2) grid, (3) error row + `X` accumulation. The only allocation is the
/// `[R, N]` product accumulator the unfused path also produced as its
/// matmul output.
pub fn quant_loss(x_rows: &Tensor, w: &Tensor, s: Option<&[f32]>,
                  group_size: usize, clip_ratio: f32) -> f64 {
    let (r, kx) = x_rows.dims2();
    let (k, n) = w.dims2();
    assert_eq!(kx, k, "activation dim {kx} vs weight K {k}");
    assert_eq!(k % group_size, 0, "K={k} % group={group_size}");
    if let Some(s) = s {
        assert_eq!(s.len(), k, "smoothing factors len");
    }
    let groups = k / group_size;
    const JB: usize = 64;
    let nbj = n.div_ceil(JB);
    let xd = &x_rows.data;
    let wd = &w.data;
    // e = X · (W - W_eff), filled block-by-block
    let mut e = vec![0.0f32; r * n];
    let mut wmin = [0.0f32; JB];
    let mut wmax = [0.0f32; JB];
    let mut delta = [0.0f32; JB];
    let mut zpt = [0.0f32; JB];
    let mut dj = [0.0f32; JB];
    for bj in 0..nbj {
        let j0 = bj * JB;
        let jw = JB.min(n - j0);
        for g in 0..groups {
            let k0 = g * group_size;
            // pass 1: per-column (min, max) of the scaled group
            wmin[..jw].fill(f32::INFINITY);
            wmax[..jw].fill(f32::NEG_INFINITY);
            for kk in k0..k0 + group_size {
                let sk = match s {
                    Some(sv) => sv[kk],
                    None => 1.0,
                };
                let row = &wd[kk * n + j0..kk * n + j0 + jw];
                for j in 0..jw {
                    let v = row[j] * sk;
                    if v < wmin[j] {
                        wmin[j] = v;
                    }
                    if v > wmax[j] {
                        wmax[j] = v;
                    }
                }
            }
            // pass 2: the group's quant grid (Eq. 1)
            for j in 0..jw {
                let (d, z) = int4_grid(wmin[j] * clip_ratio,
                                       wmax[j] * clip_ratio);
                delta[j] = d;
                zpt[j] = z;
            }
            // pass 3: per input channel, the original-frame error row
            // w - dequant(quant(s·w))/s, accumulated against X
            for kk in k0..k0 + group_size {
                let sk = match s {
                    Some(sv) => sv[kk],
                    None => 1.0,
                };
                let inv_sk = 1.0 / sk;
                let row = &wd[kk * n + j0..kk * n + j0 + jw];
                for j in 0..jw {
                    let sv = row[j] * sk;
                    let q = ((sv / delta[j]).round() + zpt[j])
                        .clamp(0.0, NIBBLE_MAX);
                    let deq = (q - zpt[j]) * delta[j];
                    dj[j] = row[j] - deq * inv_sk;
                }
                for rr in 0..r {
                    let xv = xd[rr * k + kk];
                    if xv == 0.0 {
                        continue;
                    }
                    let erow = &mut e[rr * n + j0..rr * n + j0 + jw];
                    for j in 0..jw {
                        erow[j] += xv * dj[j];
                    }
                }
            }
        }
    }
    // same row-major f64 accumulation as `frob_sq`
    let mut total = 0.0f64;
    for &v in &e {
        let v = v as f64;
        total += v * v;
    }
    total
}

/// The site whose activation feeds a given linear.
pub fn site_of(linear: &str) -> Site {
    match linear {
        "wq" | "wk" | "wv" => Site::AttnIn,
        "wo" => Site::OIn,
        "w_gate" | "w_up" => Site::MlpIn,
        "w_down" => Site::DownIn,
        _ => panic!("unknown linear {linear}"),
    }
}

/// Per-decoder-layer and total quantization loss of an effective model
/// (original-frame weights) against the original model. Normalized per
/// calibration row so sizes are comparable (the paper's Fig. 3 / Tab. 4
/// readout).
#[derive(Debug, Clone)]
pub struct ModelLoss {
    /// Loss per decoder layer (summed over its linears).
    pub per_layer: Vec<f64>,
    /// Sum over layers.
    pub total: f64,
}

/// Whole-model quantization loss of `effective` vs `orig` over the
/// calibration rows (see [`ModelLoss`]).
pub fn model_quant_loss(cfg: &ModelConfig, orig: &WeightStore,
                        effective: &WeightStore, calib: &CalibData)
    -> ModelLoss {
    let mut per_layer = Vec::with_capacity(cfg.layers);
    for layer in 0..cfg.layers {
        let mut l = 0.0;
        for lin in LAYER_LINEARS {
            let name = format!("layers.{layer}.{lin}");
            let stats = calib.stats(layer, site_of(lin));
            let rows = stats.rows.shape[0].max(1) as f64;
            l += linear_loss(&stats.rows, orig.f32(&name),
                             effective.f32(&name)) / rows;
        }
        per_layer.push(l);
    }
    let total = per_layer.iter().sum();
    ModelLoss { per_layer, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::{calib, rtn};
    use crate::util::rng::Rng;

    #[test]
    fn zero_for_identical_weights() {
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w = Tensor::from_vec(&[3, 2], vec![1., 0., 0., 1., 1., 1.]);
        assert_eq!(linear_loss(&x, &w, &w), 0.0);
    }

    #[test]
    fn positive_for_perturbed_weights() {
        let x = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::from_vec(&[2, 2], vec![1., 0., 0., 1.]);
        let mut w2 = w.clone();
        w2.data[0] += 0.1;
        assert!(linear_loss(&x, &w, &w2) > 0.0);
    }

    #[test]
    fn fused_quant_loss_matches_unfused_exactly() {
        // the hot-path contract: quant_loss == the pre-fusion pipeline
        // (clone, scale, fake-quant, unscale, linear_loss), bit-for-bit
        let mut rng = Rng::new(41);
        for (k, n, g) in [(128usize, 24usize, 128usize), (256, 17, 64)] {
            let w = Tensor::from_vec(
                &[k, n],
                (0..k * n).map(|_| rng.normal()).collect(),
            );
            let x = Tensor::from_vec(
                &[9, k],
                (0..9 * k).map(|_| rng.normal()).collect(),
            );
            let s: Vec<f32> =
                (0..k).map(|_| 0.25 + rng.f32() * 4.0).collect();
            for clip in [1.0f32, 0.9] {
                // unfused reference
                let mut scaled = w.clone();
                scaled.scale_rows(&s);
                let mut eff =
                    rtn::quantize_clipped(&scaled, g, clip).dequantize();
                let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
                eff.scale_rows(&inv);
                let want = linear_loss(&x, &w, &eff);
                let got = quant_loss(&x, &w, Some(&s), g, clip);
                assert_eq!(got, want, "k={k} n={n} g={g} clip={clip}");
            }
            // unsmoothed path
            let want =
                linear_loss(&x, &w, &rtn::quantize_clipped(&w, g, 1.0)
                    .dequantize());
            let got = quant_loss(&x, &w, None, g, 1.0);
            assert_eq!(got, want, "unsmoothed k={k}");
        }
    }

    #[test]
    fn quant_loss_zero_rows_is_zero() {
        let w = Tensor::from_vec(&[4, 2], vec![1., 2., 3., 4., 5., 6., 7.,
                                               8.]);
        let x = Tensor::zeros(&[0, 4]);
        assert_eq!(quant_loss(&x, &w, None, 2, 1.0), 0.0);
    }

    #[test]
    fn model_loss_runs_and_is_positive_under_rtn() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::default());
        let calib = calib::collect(&cfg, &w, &[vec![1, 2, 3, 4, 5]], 8, 0);
        let mut eff = w.clone();
        for layer in 0..cfg.layers {
            for lin in LAYER_LINEARS {
                let name = format!("layers.{layer}.{lin}");
                let fq = rtn::fake_quant(w.f32(&name), cfg.group_size);
                eff.set_f32(&name, fq);
            }
        }
        let ml = model_quant_loss(&cfg, &w, &eff, &calib);
        assert_eq!(ml.per_layer.len(), cfg.layers);
        assert!(ml.total > 0.0);
        assert!(ml.per_layer.iter().all(|&l| l >= 0.0));
        // identical model has zero loss
        let z = model_quant_loss(&cfg, &w, &w, &calib);
        assert_eq!(z.total, 0.0);
    }

    #[test]
    fn site_mapping_complete() {
        for lin in LAYER_LINEARS {
            let s = site_of(lin);
            assert!(s.consumers().contains(&lin));
        }
    }
}
