//! AWQ baseline (Lin et al. 2023), as the paper characterises it (§4):
//!
//! * importance factors from the **mean** |X_j| per channel (not max);
//! * scaling `s_j = mean|X_j|^alpha`, with `alpha` searched **per layer**
//!   (per smoothing unit here) against a *local* objective — the unit's
//!   own output error — using the original calibration activations, so the
//!   effect of earlier layers' quantization error on later layers is never
//!   accounted for (the error-accumulation criticism);
//! * an additional weight-clipping grid search per unit (AutoAWQ's
//!   `clip` pass), which is what makes AWQ's search markedly more
//!   expensive than SmoothQuant+'s single global grid.

use std::time::Instant;

use crate::config::{ModelConfig, QuantConfig};
use crate::model::store::WeightStore;
use crate::reffwd::Site;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_map;

use super::calib::CalibData;
use super::loss::quant_loss;
use super::smooth::apply_unit;

/// AWQ's per-unit alpha grid (20 points, matching AutoAWQ's n_grid).
pub const AWQ_ALPHA_GRID: usize = 20;
/// AWQ's clip-ratio candidates per unit.
pub const AWQ_CLIP_GRID: [f32; 4] = [1.0, 0.95, 0.9, 0.85];

/// Outcome of the AWQ per-unit (alpha, clip) grid search.
#[derive(Debug, Clone)]
pub struct AwqResult {
    /// (layer, site, alpha, clip) chosen per unit.
    pub choices: Vec<(usize, Site, f32, f32)>,
    /// Loss evaluations performed across the grids.
    pub evals: usize,
    /// Wall-clock search time.
    pub elapsed_s: f64,
}

/// Search + apply AWQ scaling in place (smoothed model out). The caller
/// then quantizes with the chosen clip ratios via [`AwqResult::clip_for`].
pub fn awq_search_and_smooth(store: &mut WeightStore, cfg: &ModelConfig,
                             calib: &CalibData, qcfg: &QuantConfig)
    -> AwqResult {
    // sqlint: allow(determinism) wall-clock timing for pipeline reporting; results unaffected
    let t0 = Instant::now();
    let mut choices = Vec::new();
    let mut evals = 0;
    // layer-by-layer, unit-by-unit: greedy local objective
    for layer in 0..cfg.layers {
        for site in Site::all() {
            let stats = calib.stats(layer, site);
            // candidate grid, evaluated in parallel
            let grid: Vec<(f32, f32)> = (0..AWQ_ALPHA_GRID)
                .flat_map(|i| {
                    let alpha = i as f32 / AWQ_ALPHA_GRID as f32;
                    AWQ_CLIP_GRID.iter().map(move |&c| (alpha, c))
                })
                .collect();
            evals += grid.len();
            // fused grid eval: no weight clone or fake-quant round trip
            // per (alpha, clip) candidate
            let losses = parallel_map(grid.len(), |gi| {
                let (alpha, clip) = grid[gi];
                let s = awq_factors(&stats.absmean, alpha);
                let rows = stats.rows.shape[0].max(1) as f64;
                let mut total = 0.0;
                for lin in site.consumers() {
                    let name = format!("layers.{layer}.{lin}");
                    total += quant_loss(&stats.rows, store.f32(&name),
                                        Some(&s), qcfg.group_size, clip)
                        / rows;
                }
                total
            });
            let best = losses
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            let (alpha, clip) = grid[best];
            let s = awq_factors(&stats.absmean, alpha);
            apply_unit(store, layer, site, &s);
            choices.push((layer, site, alpha, clip));
        }
    }
    AwqResult { choices, evals, elapsed_s: t0.elapsed().as_secs_f64() }
}

impl AwqResult {
    /// Clip ratio chosen for a unit (1.0 if absent).
    pub fn clip_for(&self, layer: usize, site: Site) -> f32 {
        self.choices
            .iter()
            .find(|c| c.0 == layer && c.1 == site)
            .map(|c| c.3)
            .unwrap_or(1.0)
    }
}

/// AWQ importance scaling: `s_j = mean|X_j|^alpha`, floored for stability.
pub fn awq_factors(act_absmean: &[f32], alpha: f32) -> Vec<f32> {
    act_absmean
        .iter()
        .map(|&a| a.max(1e-5).powf(alpha).clamp(1e-4, 1e4))
        .collect()
}

#[allow(dead_code)]
fn unused(_: &Tensor) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::{calib, loss, rtn};
    use crate::reffwd::{NoHook, RefModel};
    use crate::util::prop;

    fn setup() -> (ModelConfig, WeightStore, CalibData) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..10).map(|t| (i * 71 + t * 29) % 512).collect())
            .collect();
        let calib = calib::collect(&cfg, &w, &prompts, 24, 0);
        (cfg, w, calib)
    }

    #[test]
    fn factors_alpha_zero_is_identity() {
        let s = awq_factors(&[0.5, 3.0, 100.0], 0.0);
        prop::assert_allclose(&s, &[1.0, 1.0, 1.0], 1e-6, 1e-6, "id");
    }

    #[test]
    fn search_is_equivalence_preserving() {
        let (cfg, w, calib) = setup();
        let mut sm = w.clone();
        awq_search_and_smooth(&mut sm, &cfg, &calib,
                              &QuantConfig::default());
        let tokens = [3u32, 77, 205, 11];
        let (a, _) = RefModel::new(&cfg, &w).prefill(&tokens, &mut NoHook);
        let (b, _) = RefModel::new(&cfg, &sm).prefill(&tokens, &mut NoHook);
        prop::assert_allclose(&a.data, &b.data, 2e-3, 2e-3, "awq equiv");
    }

    #[test]
    fn search_reduces_local_loss_vs_rtn() {
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig::default();
        let mut sm = w.clone();
        let res = awq_search_and_smooth(&mut sm, &cfg, &calib, &qcfg);
        assert_eq!(res.choices.len(), cfg.layers * 4);
        assert_eq!(res.evals,
                   cfg.layers * 4 * AWQ_ALPHA_GRID * AWQ_CLIP_GRID.len());
        // quantize the AWQ-smoothed model and compare total loss vs RTN
        let mut eff_awq = sm.clone();
        let mut eff_rtn = w.clone();
        for layer in 0..cfg.layers {
            for lin in crate::model::LAYER_LINEARS {
                let name = format!("layers.{layer}.{lin}");
                let clip = res.clip_for(layer, loss::site_of(lin));
                let q = rtn::quantize_clipped(sm.f32(&name),
                                              qcfg.group_size, clip);
                eff_awq.set_f32(&name, q.dequantize());
                eff_rtn.set_f32(
                    &name,
                    rtn::fake_quant(w.f32(&name), qcfg.group_size),
                );
            }
        }
        // compare in each model's own frame via end-logit error
        let tokens = [3u32, 77, 205, 11, 460, 9];
        let m0 = RefModel::new(&cfg, &w);
        let (want, _) = m0.prefill(&tokens, &mut NoHook);
        let err = |eff: &WeightStore| {
            let (got, _) =
                RefModel::new(&cfg, eff).prefill(&tokens, &mut NoHook);
            got.sq_diff(&want)
        };
        let e_awq = err(&eff_awq);
        let e_rtn = err(&eff_rtn);
        assert!(
            e_awq < e_rtn,
            "AWQ logit err {e_awq} !< RTN {e_rtn} (outlier model)"
        );
    }
}
