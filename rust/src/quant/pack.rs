//! INT4 nibble packing (two consecutive input-channel rows per byte, low
//! nibble first) — the layout the Pallas kernel unpacks in VMEM.
//!
//! These are the *reference* pack/unpack routines (and the only path for
//! odd group sizes). The hot paths bypass them: `rtn::quantize_clipped`
//! packs nibbles in its fused quantize pass, and both
//! `QuantizedLinear::dequantize` and the host W4A16 kernel
//! (`super::kernel`) read packed bytes in place without an intermediate
//! nibble buffer.

use crate::tensor::U8Tensor;

/// Pack `q: [K, N]` nibble values (each in 0..=15) into `u8[K/2, N]`.
pub fn pack_nibbles(q: &[u8], k: usize, n: usize) -> U8Tensor {
    assert_eq!(q.len(), k * n);
    assert_eq!(k % 2, 0, "K must be even to pack");
    let mut out = vec![0u8; k / 2 * n];
    for k2 in 0..k / 2 {
        for j in 0..n {
            let lo = q[(2 * k2) * n + j];
            let hi = q[(2 * k2 + 1) * n + j];
            debug_assert!(lo <= 15 && hi <= 15, "nibble out of range");
            out[k2 * n + j] = lo | (hi << 4);
        }
    }
    U8Tensor::from_vec(&[k / 2, n], out)
}

/// Inverse of [`pack_nibbles`]: `u8[K/2, N] -> [K, N]` nibble values.
pub fn unpack_nibbles(packed: &U8Tensor) -> Vec<u8> {
    let (k2, n) = (packed.shape[0], packed.shape[1]);
    let mut out = vec![0u8; k2 * 2 * n];
    for i in 0..k2 {
        for j in 0..n {
            let b = packed.data[i * n + j];
            out[(2 * i) * n + j] = b & 0xF;
            out[(2 * i + 1) * n + j] = b >> 4;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_all_nibble_pairs() {
        // every (lo, hi) combination
        let mut q = Vec::new();
        for lo in 0..16u8 {
            for hi in 0..16u8 {
                q.push(lo);
                q.push(hi);
            }
        }
        // layout as [K=512, N=1]
        let t = pack_nibbles(&q, 512, 1);
        assert_eq!(unpack_nibbles(&t), q);
    }

    #[test]
    fn known_bytes() {
        // column layout: q[k=0..2, n=0..2]
        let q = vec![0x1, 0x2, /* k=0 */ 0xF, 0x0 /* k=1 */];
        let t = pack_nibbles(&q, 2, 2);
        assert_eq!(t.data, vec![0x1 | (0xF << 4), 0x2]);
    }

    #[test]
    fn roundtrip_random() {
        prop::check("pack/unpack roundtrip", 20, |rng| {
            let k = 2 * (1 + rng.below(64));
            let n = 1 + rng.below(16);
            let q: Vec<u8> =
                (0..k * n).map(|_| rng.below(16) as u8).collect();
            let packed = pack_nibbles(&q, k, n);
            assert_eq!(packed.shape, vec![k / 2, n]);
            assert_eq!(unpack_nibbles(&packed), q);
        });
    }
}
