//! End-to-end quantization pipeline: map a [`QuantMethod`] over an fp16
//! model, producing
//!
//! * an **effective** store (fp16 layout, fake-quantized linears) for the
//!   reference-forward eval path, and
//! * a **deploy** store (w4a16 layout: packed/scales/zeros triples) whose
//!   tensors are uploaded to the device in canonical order — the Rust
//!   equivalent of the paper's "quantize during CPU→GPU migration" loader.

use std::time::Instant;

use crate::config::{ModelConfig, QuantConfig, QuantMethod};
use crate::model::store::WeightStore;
use crate::model::{weight_names, weight_names_w4a16, LAYER_LINEARS};

use super::awq::awq_search_and_smooth;
use super::calib::CalibData;
use super::loss::{model_quant_loss, site_of, ModelLoss};
use super::rtn;
use super::search::{search_alpha_with, AlphaSearchCtx, SearchResult};
use super::smooth::smooth_model;

/// Everything produced by quantizing a model with one method.
#[derive(Debug, Clone)]
pub struct QuantOutcome {
    /// Method that produced this outcome.
    pub method: QuantMethod,
    /// fp16-layout store for `reffwd` evaluation. For smoothed methods this
    /// is the *smoothed* model with fake-quant linears (mathematically the
    /// same function as dequantizing on the fly).
    pub effective: WeightStore,
    /// w4a16-layout store (packed/scales/zeros) for the PJRT runtime; None
    /// for `Fp16`.
    pub deploy: Option<WeightStore>,
    /// Whole-model quantization loss in the original activation frame.
    pub loss: ModelLoss,
    /// Chosen smoothing strength (smoothed methods only).
    pub alpha: Option<f32>,
    /// Alpha-search trace (SmoothQuant+ only).
    pub search: Option<SearchResult>,
    /// Wall-clock quantization time.
    pub quantize_s: f64,
}

/// Quantize `model` with `method`. `calib` is required for every method
/// except `Fp16` (RTN uses it only to report the loss).
pub fn quantize_model(cfg: &ModelConfig, model: &WeightStore,
                      calib: &CalibData, method: QuantMethod,
                      qcfg: &QuantConfig) -> QuantOutcome {
    // sqlint: allow(determinism) wall-clock timing for pipeline reporting; results unaffected
    let t0 = Instant::now();
    match method {
        QuantMethod::Fp16 => QuantOutcome {
            method,
            effective: model.clone(),
            deploy: None,
            loss: ModelLoss { per_layer: vec![0.0; cfg.layers], total: 0.0 },
            alpha: None,
            search: None,
            quantize_s: t0.elapsed().as_secs_f64(),
        },
        QuantMethod::Rtn => {
            let (effective, deploy) =
                quantize_store(cfg, model, qcfg, |_, _| 1.0);
            let loss = model_quant_loss(cfg, model, &effective, calib);
            QuantOutcome {
                method, effective, deploy: Some(deploy), loss,
                alpha: None, search: None,
                quantize_s: t0.elapsed().as_secs_f64(),
            }
        }
        QuantMethod::SmoothQuantPlus => {
            // one context serves every grid point AND the per-layer
            // breakdown: absmax/stats precomputed once, fused loss, no
            // weight clones in the search loop
            let ctx = AlphaSearchCtx::new(cfg, model, calib,
                                          qcfg.group_size);
            let search = search_alpha_with(&ctx, qcfg);
            let per_layer = ctx.per_layer_losses_at(cfg.layers,
                                                    search.alpha);
            let mut smoothed = model.clone();
            smooth_model(&mut smoothed, cfg, calib, search.alpha);
            let (effective, deploy) =
                quantize_store(cfg, &smoothed, qcfg, |_, _| 1.0);
            // loss in the original frame: reuse the searched value
            let loss = ModelLoss { per_layer, total: search.loss };
            QuantOutcome {
                method, effective, deploy: Some(deploy),
                loss, alpha: Some(search.alpha), search: Some(search),
                quantize_s: t0.elapsed().as_secs_f64(),
            }
        }
        QuantMethod::Awq => {
            let mut smoothed = model.clone();
            let res =
                awq_search_and_smooth(&mut smoothed, cfg, calib, qcfg);
            let (effective, deploy) =
                quantize_store(cfg, &smoothed, qcfg, |layer, lin| {
                    res.clip_for(layer, site_of(lin))
                });
            let loss = awq_frame_loss(cfg, model, &smoothed, &effective,
                                      calib);
            QuantOutcome {
                method, effective, deploy: Some(deploy),
                loss, alpha: None, search: None,
                quantize_s: t0.elapsed().as_secs_f64(),
            }
        }
    }
}

/// Quantize every decoder linear of `src` (already smoothed if needed),
/// producing the fake-quant effective store and the packed deploy store.
/// `clip(layer, lin)` supplies AWQ clip ratios (1.0 = none).
///
/// Both stores are built in one pass over the canonical order; the
/// packed/scales/zeros tensors are *moved* into the deploy store (the
/// pre-fusion implementation cloned the whole source store and then
/// re-cloned every quantized triple on push).
fn quantize_store<F: Fn(usize, &str) -> f32>(
    cfg: &ModelConfig, src: &WeightStore, qcfg: &QuantConfig, clip: F)
    -> (WeightStore, WeightStore) {
    let mut effective = WeightStore::new();
    let mut deploy = WeightStore::new();
    for name in weight_names(cfg) {
        let base = name.rsplit('.').next().unwrap();
        if name.starts_with("layers.") && LAYER_LINEARS.contains(&base) {
            let layer: usize =
                name.split('.').nth(1).unwrap().parse().unwrap();
            let q = rtn::quantize_clipped(src.f32(&name), qcfg.group_size,
                                          clip(layer, base));
            effective.push_f32(&name, q.dequantize());
            let rtn::QuantizedLinear { packed, scales, zeros, .. } = q;
            deploy.push_u8(&format!("{name}.packed"), packed);
            deploy.push_f32(&format!("{name}.scales"), scales);
            deploy.push_f32(&format!("{name}.zeros"), zeros);
        } else {
            effective.push_f32(&name, src.f32(&name).clone());
            deploy.push_f32(&name, src.f32(&name).clone());
        }
    }
    debug_assert_eq!(deploy.names(), &weight_names_w4a16(cfg)[..]);
    (effective, deploy)
}

/// AWQ loss in the original frame: undo the AWQ row scaling analytically
/// (eff_orig = diag(s)^-1 · eff_smoothed, where s = smoothed / orig rows).
fn awq_frame_loss(cfg: &ModelConfig, orig: &WeightStore,
                  smoothed: &WeightStore, effective: &WeightStore,
                  calib: &CalibData) -> ModelLoss {
    use super::loss::linear_loss;
    let mut per_layer = Vec::with_capacity(cfg.layers);
    for layer in 0..cfg.layers {
        let mut l = 0.0;
        for lin in LAYER_LINEARS {
            let name = format!("layers.{layer}.{lin}");
            let w0 = orig.f32(&name);
            let ws = smoothed.f32(&name);
            let we = effective.f32(&name);
            // per-row scale applied by AWQ: s_k = ws[k,:] / w0[k,:]
            let (k, n) = w0.dims2();
            let mut eff0 = we.clone();
            for kk in 0..k {
                // recover s from the first column with a non-tiny weight
                let mut s = 1.0f32;
                for j in 0..n {
                    let a = w0.data[kk * n + j];
                    if a.abs() > 1e-8 {
                        s = ws.data[kk * n + j] / a;
                        break;
                    }
                }
                let inv = 1.0 / s;
                for j in 0..n {
                    eff0.data[kk * n + j] *= inv;
                }
            }
            let stats = calib.stats(layer, site_of(lin));
            let rows = stats.rows.shape[0].max(1) as f64;
            l += linear_loss(&stats.rows, w0, &eff0) / rows;
        }
        per_layer.push(l);
    }
    let total = per_layer.iter().sum();
    ModelLoss { per_layer, total }
}

/// Build the fp16-layout deploy store (for serving the FP16 baseline).
pub fn fp16_deploy(cfg: &ModelConfig, model: &WeightStore) -> WeightStore {
    let mut deploy = WeightStore::new();
    for name in weight_names(cfg) {
        deploy.push_f32(&name, model.f32(&name).clone());
    }
    deploy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::calib;
    use crate::reffwd::{NoHook, RefModel};

    fn setup() -> (ModelConfig, WeightStore, CalibData) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..10).map(|t| (i * 71 + t * 29) % 512).collect())
            .collect();
        let calib = calib::collect(&cfg, &w, &prompts, 24, 0);
        (cfg, w, calib)
    }

    #[test]
    fn deploy_store_layout() {
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig::default();
        let out = quantize_model(&cfg, &w, &calib, QuantMethod::Rtn, &qcfg);
        let deploy = out.deploy.unwrap();
        let names: Vec<String> = deploy.names().to_vec();
        assert_eq!(names, weight_names_w4a16(&cfg));
        let p = deploy.u8("layers.0.wq.packed");
        assert_eq!(p.shape, vec![cfg.dim / 2, cfg.dim]);
    }

    #[test]
    fn method_ordering_on_outlier_model() {
        // loss(SQ+) < loss(RTN); FP16 == 0 — the paper's core ordering
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig::default();
        let fp = quantize_model(&cfg, &w, &calib, QuantMethod::Fp16, &qcfg);
        let rtn = quantize_model(&cfg, &w, &calib, QuantMethod::Rtn, &qcfg);
        let sqp = quantize_model(&cfg, &w, &calib,
                                 QuantMethod::SmoothQuantPlus, &qcfg);
        assert_eq!(fp.loss.total, 0.0);
        assert!(sqp.loss.total < rtn.loss.total,
                "SQ+ {} !< RTN {}", sqp.loss.total, rtn.loss.total);
        assert!(sqp.alpha.is_some());
    }

    #[test]
    fn effective_model_close_to_fp16_for_sqplus() {
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig::default();
        let sqp = quantize_model(&cfg, &w, &calib,
                                 QuantMethod::SmoothQuantPlus, &qcfg);
        let rtn = quantize_model(&cfg, &w, &calib, QuantMethod::Rtn, &qcfg);
        let tokens = [3u32, 77, 205, 11, 460, 9];
        let (want, _) =
            RefModel::new(&cfg, &w).prefill(&tokens, &mut NoHook);
        let err = |s: &WeightStore| {
            let (got, _) =
                RefModel::new(&cfg, s).prefill(&tokens, &mut NoHook);
            got.sq_diff(&want)
        };
        let e_sqp = err(&sqp.effective);
        let e_rtn = err(&rtn.effective);
        assert!(e_sqp < e_rtn, "SQ+ logit err {e_sqp} !< RTN {e_rtn}");
    }

    #[test]
    fn sqplus_search_cheaper_than_awq() {
        // the paper's "1/5 of the time taken by AWQ" claim, in evals
        let (cfg, w, calib) = setup();
        let qcfg = QuantConfig::default();
        let sqp = quantize_model(&cfg, &w, &calib,
                                 QuantMethod::SmoothQuantPlus, &qcfg);
        let evals_sqp = sqp.search.as_ref().unwrap().evals;
        let evals_awq = cfg.layers * 4 * super::super::awq::AWQ_ALPHA_GRID
            * super::super::awq::AWQ_CLIP_GRID.len();
        assert!(evals_sqp * 3 < evals_awq,
                "SQ+ evals {evals_sqp} vs AWQ {evals_awq}");
    }

    #[test]
    fn fp16_deploy_layout() {
        let (cfg, w, _) = setup();
        let d = fp16_deploy(&cfg, &w);
        assert_eq!(d.names(), &weight_names(&cfg)[..]);
    }
}
