//! Model substrate: canonical weight naming/ordering (the contract shared
//! with `python/compile/configs.py` and the artifact manifest), the weight
//! store with its on-disk `.sqw` format, and seeded initialization with
//! outlier-channel injection.

pub mod init;
pub mod store;

use crate::config::ModelConfig;

/// The 7 quantizable linears of a decoder layer, in canonical order —
/// the set expanded to `(packed, scales, zeros)` triples under W4A16.
pub const LAYER_LINEARS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// Canonical FP16 weight order (must match python `configs.weight_names`).
pub fn weight_names(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec!["embed".to_string()];
    for i in 0..cfg.layers {
        for w in ["attn_norm", "wq", "wk", "wv", "wo",
                  "mlp_norm", "w_gate", "w_up", "w_down"] {
            names.push(format!("layers.{i}.{w}"));
        }
    }
    names.push("final_norm".into());
    names.push("lm_head".into());
    names
}

/// Canonical W4A16 parameter order: each decoder linear expands in place to
/// (packed, scales, zeros); everything else stays a single f32 tensor.
pub fn weight_names_w4a16(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec![];
    for n in weight_names(cfg) {
        let base = n.rsplit('.').next().unwrap();
        if n.starts_with("layers.") && LAYER_LINEARS.contains(&base) {
            names.push(format!("{n}.packed"));
            names.push(format!("{n}.scales"));
            names.push(format!("{n}.zeros"));
        } else {
            names.push(n);
        }
    }
    names
}

/// Shape of a canonical fp16 weight.
pub fn weight_shape(cfg: &ModelConfig, name: &str) -> Vec<usize> {
    let base = name.rsplit('.').next().unwrap();
    match base {
        "embed" => vec![cfg.vocab, cfg.dim],
        "lm_head" => vec![cfg.dim, cfg.vocab],
        "attn_norm" | "mlp_norm" | "final_norm" => vec![cfg.dim],
        _ => {
            let (_, k, n) = cfg
                .linear_shapes()
                .into_iter()
                .find(|&(w, _, _)| w == base)
                .unwrap_or_else(|| panic!("unknown weight {name}"));
            vec![k, n]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_counts_match_python() {
        let cfg = ModelConfig::tiny();
        assert_eq!(weight_names(&cfg).len(), 2 + 1 + 9 * cfg.layers);
        assert_eq!(
            weight_names_w4a16(&cfg).len(),
            2 + 1 + (2 + 7 * 3) * cfg.layers
        );
    }

    #[test]
    fn w4a16_triple_adjacency() {
        let cfg = ModelConfig::tiny();
        let names = weight_names_w4a16(&cfg);
        let i = names.iter().position(|n| n == "layers.0.wq.packed").unwrap();
        assert_eq!(names[i + 1], "layers.0.wq.scales");
        assert_eq!(names[i + 2], "layers.0.wq.zeros");
    }

    #[test]
    fn shapes() {
        let cfg = ModelConfig::small();
        assert_eq!(weight_shape(&cfg, "embed"), vec![cfg.vocab, cfg.dim]);
        assert_eq!(weight_shape(&cfg, "layers.3.w_down"),
                   vec![cfg.ffn, cfg.dim]);
        assert_eq!(weight_shape(&cfg, "layers.0.attn_norm"), vec![cfg.dim]);
    }
}
