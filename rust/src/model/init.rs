//! Seeded model initialization with **outlier-channel injection**.
//!
//! The paper's mechanism requires activation outliers that are (i) ~100x
//! the median magnitude and (ii) pinned to a small set of fixed channels
//! across tokens (its Figures 1-2). Untrained random weights do not produce
//! this, so we inject it the way trained LLMs express it: a few RMSNorm
//! gain channels are scaled far above 1, which multiplies those channels of
//! every token entering the attached linears — exactly the fixed-channel,
//! token-independent pattern LLM.int8() documented. Per-channel heavy
//! tails are added to the hidden stream via the embedding columns.

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::store::WeightStore;
use super::{weight_names, weight_shape};

/// Outlier-injection settings (DESIGN.md §5 substitution table).
#[derive(Debug, Clone)]
pub struct InitSpec {
    /// Base RNG seed; every weight forks a name-hashed substream off it.
    pub seed: u64,
    /// Number of outlier channels per norm (0 disables injection).
    pub outlier_channels: usize,
    /// Gain multiplier applied to those channels (paper reports ~100x
    /// activation amplitudes; 30-100 reproduces that range downstream).
    pub outlier_scale: f32,
}

impl Default for InitSpec {
    fn default() -> Self {
        InitSpec { seed: 0, outlier_channels: 8, outlier_scale: 60.0 }
    }
}

impl InitSpec {
    /// Init with outlier injection disabled (the "benign" ablation arm
    /// where RTN already matches FP16).
    pub fn benign(seed: u64) -> Self {
        InitSpec { seed, outlier_channels: 0, outlier_scale: 1.0 }
    }
    /// Init with an explicit outlier channel count and gain scale.
    pub fn with_outliers(seed: u64, channels: usize, scale: f32) -> Self {
        InitSpec { seed, outlier_channels: channels, outlier_scale: scale }
    }
}

/// Build a canonical fp16 [`WeightStore`] for `cfg`.
pub fn init_weights(cfg: &ModelConfig, spec: &InitSpec) -> WeightStore {
    let mut rng = Rng::new(spec.seed);
    let mut store = WeightStore::new();
    // Fixed outlier channel set, shared across layers: the paper observes
    // the *same* channels misbehaving throughout the network.
    let outliers = if spec.outlier_channels > 0 {
        rng.fork(0xA11).choose_k(cfg.dim, spec.outlier_channels)
    } else {
        vec![]
    };

    let mut embed_copy: Option<Tensor> = None;
    for name in weight_names(cfg) {
        let shape = weight_shape(cfg, &name);
        let base = name.rsplit('.').next().unwrap();
        let t = match base {
            // lm_head is tied to the embedding (transposed, plus noise):
            // the residual stream correlates with token embeddings, so a
            // tied head yields *confident* next-token distributions — the
            // property that makes trained LLMs quantization-lossless when
            // the error is small, and measurably broken when outliers
            // amplify it. Without this, untrained logits are pure noise
            // and argmax agreement cannot distinguish methods.
            "lm_head" => {
                let e = embed_copy.as_ref().expect("embed precedes lm_head");
                let (v, d) = (cfg.vocab, cfg.dim);
                let mut t = Tensor::zeros(&[d, v]);
                let mut r = rng.fork(hash_name(&name));
                let noise = 0.15 / (d as f32).sqrt();
                for i in 0..d {
                    for j in 0..v {
                        t.data[i * v + j] =
                            e.data[j * d + i] * 3.0 + noise * r.normal();
                    }
                }
                t
            }
            "attn_norm" | "mlp_norm" => {
                let mut t = Tensor::ones(&shape);
                // mild gain noise, then the injected outlier channels
                let mut r = rng.fork(hash_name(&name));
                for v in &mut t.data {
                    *v += 0.05 * r.normal();
                }
                for &c in &outliers {
                    // vary strength a little per layer/channel: 0.5-1x
                    t.data[c] = spec.outlier_scale * (0.5 + 0.5 * r.f32());
                }
                t
            }
            "final_norm" => Tensor::ones(&shape),
            _ => {
                // fan-in scaled gaussian, with heavy-tailed per-input-
                // channel scales on the embedding so hidden activations
                // spread like trained models' do.
                let fan_in = shape[0] as f32;
                // GPT-2-style residual scaling on the projections that
                // write into the residual stream: keeps per-layer updates
                // small relative to the stream (as in trained LLMs), so
                // the tied-head confidence survives depth.
                let resid = if base == "wo" || base == "w_down" {
                    1.0 / (2.0 * cfg.layers as f32).sqrt()
                } else {
                    1.0
                };
                let mut r = rng.fork(hash_name(&name));
                let mut t = Tensor::zeros(&shape);
                for v in &mut t.data {
                    *v = r.normal() / fan_in.sqrt() * resid;
                }
                if base == "embed" {
                    embed_copy = Some(t.clone());
                }
                t
            }
        };
        store.push_f32(&name, t);
    }
    store
}

/// The channels injected by `init_weights` for a given seed (test hook and
/// Fig 2 annotation).
pub fn injected_channels(cfg: &ModelConfig, spec: &InitSpec) -> Vec<usize> {
    if spec.outlier_channels == 0 {
        return vec![];
    }
    let mut rng = Rng::new(spec.seed);
    rng.fork(0xA11).choose_k(cfg.dim, spec.outlier_channels)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_and_deterministic() {
        let cfg = ModelConfig::tiny();
        let a = init_weights(&cfg, &InitSpec::default());
        a.check_canonical_fp16(&cfg).unwrap();
        let b = init_weights(&cfg, &InitSpec::default());
        assert_eq!(a.f32("layers.0.wq").data, b.f32("layers.0.wq").data);
        let c = init_weights(&cfg, &InitSpec { seed: 1, ..Default::default() });
        assert_ne!(a.f32("layers.0.wq").data, c.f32("layers.0.wq").data);
    }

    #[test]
    fn outliers_injected_in_norm_gains() {
        let cfg = ModelConfig::tiny();
        let spec = InitSpec::with_outliers(3, 4, 50.0);
        let w = init_weights(&cfg, &spec);
        let ch = injected_channels(&cfg, &spec);
        assert_eq!(ch.len(), 4);
        let g = w.f32("layers.0.attn_norm");
        for &c in &ch {
            assert!(g.data[c] >= 25.0, "channel {c} gain {}", g.data[c]);
        }
        // non-outlier channels stay near 1
        let normal: Vec<f32> = g
            .data
            .iter()
            .enumerate()
            .filter(|(i, _)| !ch.contains(i))
            .map(|(_, &v)| v)
            .collect();
        assert!(normal.iter().all(|v| v.abs() < 2.0));
    }

    #[test]
    fn benign_init_has_no_outliers() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::benign(0));
        let g = w.f32("layers.1.mlp_norm");
        assert!(g.data.iter().all(|v| v.abs() < 2.0));
    }

    #[test]
    fn weight_scale_is_fan_in() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::default());
        let wq = w.f32("layers.0.wq");
        let rms = (wq.frob_sq() / wq.numel() as f64).sqrt();
        let want = 1.0 / (cfg.dim as f64).sqrt();
        assert!((rms / want - 1.0).abs() < 0.1, "rms {rms} want {want}");
    }
}
