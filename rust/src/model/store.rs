//! Weight store: named tensors in canonical order + the `.sqw` on-disk
//! format (our stand-in for safetensors; magic `SQW1`, little-endian).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::{Tensor, U8Tensor};

/// A named tensor: fp32 host data or packed nibbles.
#[derive(Debug, Clone, PartialEq)]
pub enum Entry {
    /// Full-precision host tensor (norms, embeddings, fp16 linears).
    F32(Tensor),
    /// Packed-nibble / byte tensor (W4A16 `packed` payloads).
    U8(U8Tensor),
}

impl Entry {
    /// Shape of the underlying tensor, whichever variant it is.
    pub fn shape(&self) -> &[usize] {
        match self {
            Entry::F32(t) => &t.shape,
            Entry::U8(t) => &t.shape,
        }
    }
    /// The f32 tensor; panics if this entry holds packed bytes.
    pub fn as_f32(&self) -> &Tensor {
        match self {
            Entry::F32(t) => t,
            Entry::U8(_) => panic!("expected f32 tensor"),
        }
    }
    /// The u8 tensor; panics if this entry holds f32 data.
    pub fn as_u8(&self) -> &U8Tensor {
        match self {
            Entry::U8(t) => t,
            Entry::F32(_) => panic!("expected u8 tensor"),
        }
    }
}

/// Ordered collection of named tensors. Order is the canonical parameter
/// order fed positionally to the PJRT executables.
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    names: Vec<String>,
    index: HashMap<String, usize>,
    entries: Vec<Entry>,
}

impl WeightStore {
    /// Empty store; tensors append in canonical order via `push*`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a named entry (panics on a duplicate name — the canonical
    /// order admits each parameter exactly once).
    pub fn push(&mut self, name: &str, e: Entry) {
        assert!(
            !self.index.contains_key(name),
            "duplicate weight name {name}"
        );
        self.index.insert(name.to_string(), self.entries.len());
        self.names.push(name.to_string());
        self.entries.push(e);
    }
    /// Append an f32 tensor.
    pub fn push_f32(&mut self, name: &str, t: Tensor) {
        self.push(name, Entry::F32(t));
    }
    /// Append a packed u8 tensor.
    pub fn push_u8(&mut self, name: &str, t: U8Tensor) {
        self.push(name, Entry::U8(t));
    }

    /// Number of stored tensors.
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    /// True when no tensors have been pushed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    /// Names in push (canonical) order.
    pub fn names(&self) -> &[String] {
        &self.names
    }
    /// Whether `name` has been pushed.
    pub fn contains(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Entry by name (panics when absent — a missing canonical weight
    /// is a programming error, not an I/O condition).
    pub fn get(&self, name: &str) -> &Entry {
        let i = *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("missing weight {name}"));
        &self.entries[i]
    }
    /// f32 tensor by name (panics when absent or packed).
    pub fn f32(&self, name: &str) -> &Tensor {
        self.get(name).as_f32()
    }
    /// u8 tensor by name (panics when absent or f32).
    pub fn u8(&self, name: &str) -> &U8Tensor {
        self.get(name).as_u8()
    }
    /// Mutable f32 tensor by name (smoothing edits weights in place).
    pub fn f32_mut(&mut self, name: &str) -> &mut Tensor {
        let i = *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("missing weight {name}"));
        match &mut self.entries[i] {
            Entry::F32(t) => t,
            Entry::U8(_) => panic!("expected f32 tensor {name}"),
        }
    }
    /// Replace an existing entry with an f32 tensor (same name/slot).
    pub fn set_f32(&mut self, name: &str, t: Tensor) {
        let i = *self.index.get(name).expect("missing weight");
        self.entries[i] = Entry::F32(t);
    }

    /// Iterate `(name, entry)` pairs in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Entry)> {
        self.names.iter().zip(self.entries.iter())
    }

    /// Total bytes of tensor data (f32 = 4 B/elem, u8 = 1 B/elem).
    pub fn data_bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|e| match e {
                Entry::F32(t) => 4 * t.numel(),
                Entry::U8(t) => t.numel(),
            })
            .sum()
    }

    /// Verify names/order against the canonical fp16 layout.
    pub fn check_canonical_fp16(&self, cfg: &ModelConfig) -> Result<()> {
        let want = super::weight_names(cfg);
        if self.names != want {
            bail!(
                "store has {} names, canonical fp16 wants {}",
                self.names.len(),
                want.len()
            );
        }
        for name in &want {
            let got = self.get(name).shape().to_vec();
            let exp = super::weight_shape(cfg, name);
            if got != exp {
                bail!("{name}: shape {got:?}, want {exp:?}");
            }
        }
        Ok(())
    }

    // ------------------------------------------------------ .sqw format

    /// Serialize to the `.sqw` format (magic `SQW1`, little-endian;
    /// per-entry: name, dtype tag, shape, raw data).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(b"SQW1")?;
        f.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, e) in self.iter() {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            let (dtype, shape): (u8, &[usize]) = match e {
                Entry::F32(t) => (0, &t.shape),
                Entry::U8(t) => (1, &t.shape),
            };
            f.write_all(&[dtype])?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match e {
                Entry::F32(t) => {
                    for v in &t.data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Entry::U8(t) => f.write_all(&t.data)?,
            }
        }
        Ok(())
    }

    /// Inverse of [`WeightStore::save`]; rejects bad magic or dtypes.
    pub fn load(path: &Path) -> Result<WeightStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"SQW1" {
            bail!("bad magic {magic:?}");
        }
        let count = read_u32(&mut f)? as usize;
        let mut store = WeightStore::new();
        for _ in 0..count {
            let nlen = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut dt = [0u8; 1];
            f.read_exact(&mut dt)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = shape.iter().product();
            match dt[0] {
                0 => {
                    let mut bytes = vec![0u8; numel * 4];
                    f.read_exact(&mut bytes)?;
                    let data = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    store.push_f32(&name, Tensor::from_vec(&shape, data));
                }
                1 => {
                    let mut data = vec![0u8; numel];
                    f.read_exact(&mut data)?;
                    store.push_u8(&name, U8Tensor::from_vec(&shape, data));
                }
                d => bail!("bad dtype {d}"),
            }
        }
        Ok(store)
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WeightStore {
        let mut s = WeightStore::new();
        s.push_f32("a", Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        s.push_u8("b.packed", U8Tensor::from_vec(&[2, 1], vec![0xab, 0x3]));
        s.push_f32("c", Tensor::from_vec(&[3], vec![-1.0, 0.0, 1.5]));
        s
    }

    #[test]
    fn ordered_access() {
        let s = sample();
        assert_eq!(s.names(), &["a", "b.packed", "c"]);
        assert_eq!(s.f32("a").data, vec![1., 2., 3., 4.]);
        assert_eq!(s.u8("b.packed").data, vec![0xab, 0x3]);
        assert_eq!(s.data_bytes(), 16 + 2 + 12);
    }

    #[test]
    #[should_panic]
    fn duplicate_name_panics() {
        let mut s = sample();
        s.push_f32("a", Tensor::zeros(&[1]));
    }

    #[test]
    fn sqw_roundtrip() {
        let s = sample();
        let dir = std::env::temp_dir().join("sqplus_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.sqw");
        s.save(&p).unwrap();
        let l = WeightStore::load(&p).unwrap();
        assert_eq!(l.names(), s.names());
        assert_eq!(l.f32("a"), s.f32("a"));
        assert_eq!(l.u8("b.packed"), s.u8("b.packed"));
        assert_eq!(l.f32("c"), s.f32("c"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("sqplus_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.sqw");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(WeightStore::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
