//! # SmoothQuant+ — 4-bit post-training weight quantization for LLM serving
//!
//! Reproduction of *SmoothQuant+: Accurate and Efficient 4-bit Post-Training
//! Weight Quantization for LLM* (ZTE, 2023) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — a vLLM-shaped serving engine (continuous
//!   batching, chunked prefill, paged KV accounting, content-hash prefix
//!   caching with sliding-window eviction, preemption) behind a
//!   multi-replica cache-aware router, plus the full quantization
//!   library: group-wise INT4 RTN, SmoothQuant+ smoothing with global
//!   alpha search, and an AWQ baseline.
//! * **L2/L1 (`python/compile`)** — the Llama-family forward pass in JAX
//!   with a Pallas W4A16 dequant-matmul kernel, AOT-lowered once to HLO
//!   text and executed here through the PJRT C API (`xla` crate). Python
//!   never runs on the request path.
//!
//! See the repo-root `README.md` for the crate layout and feature
//! flags, and `docs/ARCHITECTURE.md` for the end-to-end serving
//! walkthrough (block lifecycle, chunked prefill, worked cache-hit
//! example).

// The serving coordinator, the quantization library, the runtime, the
// model substrate, the reference forward pass, and the lint passes are
// fully documented; the remaining modules are explicitly allowed
// below until their own rustdoc passes land (tracked in ROADMAP.md).
// New items in documented modules must carry docs — CI runs
// `cargo doc --no-deps` with warnings denied.
#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod lint;
pub mod model;
pub mod quant;
pub mod reffwd;
pub mod runtime;
pub mod server;
#[allow(missing_docs)]
pub mod tensor;
#[allow(missing_docs)]
pub mod tokenizer;
#[allow(missing_docs)]
pub mod util;
