//! # SmoothQuant+ — 4-bit post-training weight quantization for LLM serving
//!
//! Reproduction of *SmoothQuant+: Accurate and Efficient 4-bit Post-Training
//! Weight Quantization for LLM* (ZTE, 2023) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — a vLLM-shaped serving engine (continuous
//!   batching, paged KV accounting, preemption) plus the full quantization
//!   library: group-wise INT4 RTN, SmoothQuant+ smoothing with global
//!   alpha search, and an AWQ baseline.
//! * **L2/L1 (`python/compile`)** — the Llama-family forward pass in JAX
//!   with a Pallas W4A16 dequant-matmul kernel, AOT-lowered once to HLO
//!   text and executed here through the PJRT C API (`xla` crate). Python
//!   never runs on the request path.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for measured
//! results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod reffwd;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod util;
