//! `sqlint` CLI — run the project-invariant lint passes.
//!
//! ```text
//! sqlint [--baseline FILE] [--write-baseline FILE] [PATH ...]
//! ```
//!
//! Paths default to `src tests` (relative to the current directory —
//! run from `rust/`, or use `make lint`). Exit codes: 0 clean, 1
//! findings, 2 usage or I/O error. Findings print to stdout as
//! `path:line: [pass] message`; the summary line goes to stderr.

use std::path::PathBuf;
use std::process::ExitCode;

use sqplus::lint;

fn usage() -> &'static str {
    "usage: sqlint [--baseline FILE] [--write-baseline FILE] [PATH ...]\n\
     \n\
     Runs the panic/determinism/locks/wire/events passes over the given\n\
     roots\n\
     (default: src tests). --baseline filters known findings;\n\
     --write-baseline records the current findings and exits 0."
}

fn main() -> ExitCode {
    let mut baseline: Option<PathBuf> = None;
    let mut write_baseline: Option<PathBuf> = None;
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "-h" | "--help" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            "--baseline" => {
                let Some(f) = args.next() else {
                    eprintln!("sqlint: --baseline needs a file\n{}", usage());
                    return ExitCode::from(2);
                };
                baseline = Some(PathBuf::from(f));
            }
            "--write-baseline" => {
                let Some(f) = args.next() else {
                    eprintln!(
                        "sqlint: --write-baseline needs a file\n{}",
                        usage()
                    );
                    return ExitCode::from(2);
                };
                write_baseline = Some(PathBuf::from(f));
            }
            s if s.starts_with('-') => {
                eprintln!("sqlint: unknown flag `{s}`\n{}", usage());
                return ExitCode::from(2);
            }
            s => roots.push(PathBuf::from(s)),
        }
    }
    if roots.is_empty() {
        roots.push(PathBuf::from("src"));
        roots.push(PathBuf::from("tests"));
    }
    let diags = match lint::run_paths(&roots) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("sqlint: {e}");
            return ExitCode::from(2);
        }
    };
    if let Some(out) = write_baseline {
        let mut text = String::from(
            "# sqlint baseline — one `pass path:line` key per line.\n\
             # Regenerate with: sqlint --write-baseline <this file> <roots>\n",
        );
        for d in &diags {
            text.push_str(&d.baseline_key());
            text.push('\n');
        }
        if let Err(e) = std::fs::write(&out, text) {
            eprintln!("sqlint: writing {}: {e}", out.display());
            return ExitCode::from(2);
        }
        eprintln!(
            "sqlint: wrote {} finding(s) to {}",
            diags.len(),
            out.display()
        );
        return ExitCode::SUCCESS;
    }
    let diags = if let Some(b) = baseline {
        let known = match lint::load_baseline(&b) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("sqlint: reading {}: {e}", b.display());
                return ExitCode::from(2);
            }
        };
        lint::apply_baseline(diags, &known)
    } else {
        diags
    };
    for d in &diags {
        println!("{}", d.render());
    }
    eprintln!("sqlint: {} finding(s)", diags.len());
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
