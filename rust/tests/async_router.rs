//! Threaded front-end ([`AsyncRouter`]) properties over deterministic
//! fake cores — the async counterpart of `router_properties.rs`.
//!
//! The content-determined fake model makes exact stream goldens
//! feasible even though worker threads interleave nondeterministically:
//! any correct scheduling/batching/replay must produce bit-identical
//! per-request token streams. Locked down:
//!
//! * **stream-identity golden**: the N-worker threaded router produces
//!   the same `(id, output, finish)` triples as the synchronous
//!   [`Router`] and as a bare [`FakeCore`] on the same work, and every
//!   incrementally streamed token sequence equals the finished output
//!   (indices contiguous from 0);
//! * a replica **killed mid-stream** on its own worker thread loses no
//!   request and duplicates no token: in-flight work replays onto the
//!   survivor and streams stay bit-identical to the fault-free run,
//!   with the dead replica purged from the cache directory;
//! * a transient **brown-out recovers** on the worker's own
//!   retry/backoff clock without death or replay;
//! * **admission control sheds deterministically**: back-to-back
//!   submissions are judged against the front end's own outstanding
//!   counts, which cannot change between submits;
//! * **KV migration over the Export/Exported handshake**: a warm
//!   rehit forced onto a cold replica parks, the donor's worker ships
//!   its stashed blocks, and the deferred preloaded submit serves the
//!   suffix only — with identical streams to the migration-off
//!   control and strictly fewer cold prefill tokens; a donor dying
//!   mid-handshake, a transient export hiccup, or a receiver
//!   rejecting the deferred submit each degrade to plain recompute
//!   without hanging placement or perturbing any stream.

use std::collections::HashMap;
use std::time::Duration;

use sqplus::config::{EngineConfig, RouterConfig, RoutingPolicy};
use sqplus::coordinator::fake::FakeCore;
use sqplus::coordinator::fault::{FaultSpec, FaultyCore};
use sqplus::coordinator::replica::{ReplicaCore, ReplicaStats};
use sqplus::coordinator::router::{RoutedFinish, Router, RouterStats};
use sqplus::coordinator::sequence::{FinishReason, SamplingParams};
use sqplus::coordinator::worker::{AsyncRouter, RouterEvent};

fn ecfg(block_size: usize) -> EngineConfig {
    EngineConfig {
        max_running: 4,
        max_batch_tokens: 64,
        decode_batches: vec![1, 2, 4, 8],
        prefill_buckets: vec![(4, 64)],
        block_size,
        ..Default::default()
    }
}

fn sp(max_new: usize) -> SamplingParams {
    SamplingParams { max_new_tokens: max_new, ..Default::default() }
}

/// Deterministic work list: 6 unique prompts with mixed budgets.
fn work_list() -> Vec<(Vec<u32>, usize)> {
    (0..6u32)
        .map(|i| {
            let p: Vec<u32> = (0..(6 + i as usize % 5) as u32)
                .map(|t| 500 + i * 97 + t)
                .collect();
            (p, 2 + i as usize % 4)
        })
        .collect()
}

type Outs = Vec<(u64, Vec<u32>, Option<FinishReason>)>;

/// Drive a bare core over the work list; the reference streams.
fn run_bare(mut core: FakeCore, work: &[(Vec<u32>, usize)]) -> Outs {
    for (p, max_new) in work {
        core.submit(p.clone(), sp(*max_new)).unwrap();
    }
    let mut out: Outs = vec![];
    for _ in 0..10_000 {
        core.step().unwrap();
        for q in core.take_finished() {
            out.push((q.id, q.output.clone(), q.finish));
        }
        if !core.has_work() {
            break;
        }
    }
    assert!(!core.has_work(), "bare core did not drain");
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Drive the synchronous router over the same work.
fn run_sync(
    cores: Vec<FakeCore>,
    rcfg: RouterConfig,
    work: &[(Vec<u32>, usize)],
) -> Outs {
    let mut router = Router::new(cores, rcfg);
    for (p, max_new) in work {
        router.submit(p.clone(), sp(*max_new));
    }
    router.run_to_completion(10_000).unwrap();
    let mut out: Outs = router
        .take_finished()
        .into_iter()
        .map(|f| (f.id, f.seq.output, f.seq.finish))
        .collect();
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Everything a threaded run produced, for assertions after the fact.
struct AsyncRun {
    outs: Outs,
    fins: Vec<RoutedFinish>,
    /// Incrementally streamed tokens per request, in index order
    /// (contiguity is asserted as the events arrive).
    streams: HashMap<u64, Vec<u32>>,
    stats: Vec<ReplicaStats>,
    rstats: RouterStats,
    /// Whether the cache directory still hints at replica `i`
    /// (snapshot taken after the last request finished).
    dir_mentions: Vec<bool>,
}

fn apply(
    ev: RouterEvent,
    streams: &mut HashMap<u64, Vec<u32>>,
    fins: &mut Vec<RoutedFinish>,
) {
    match ev {
        RouterEvent::Token { id, index, token } => {
            let s = streams.entry(id).or_default();
            assert_eq!(index, s.len(),
                       "stream {id}: non-contiguous token index");
            s.push(token);
        }
        RouterEvent::Finished(f) => fins.push(f),
    }
}

/// Submit the whole work list back-to-back, poll to completion, then
/// shut down and fold in the final events.
fn run_async<C>(
    cores: Vec<C>,
    rcfg: RouterConfig,
    work: &[(Vec<u32>, usize)],
) -> AsyncRun
where
    C: ReplicaCore + Send + 'static,
{
    let mut router = AsyncRouter::new(cores, rcfg);
    for (p, max_new) in work {
        router.submit(p.clone(), sp(*max_new));
    }
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut polls = 0usize;
    while fins.len() < work.len() {
        polls += 1;
        assert!(polls < 3_000,
                "async router did not drain: {}/{} finished",
                fins.len(), work.len());
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let stats = router.stats();
    let rstats = router.router_stats();
    let dir_mentions = (0..stats.len())
        .map(|i| router.directory().mentions_replica(i))
        .collect();
    for ev in router.shutdown() {
        apply(ev, &mut streams, &mut fins);
    }
    let mut outs: Outs = fins
        .iter()
        .map(|f| (f.id, f.seq.output.clone(), f.seq.finish))
        .collect();
    outs.sort_by_key(|(id, _, _)| *id);
    AsyncRun { outs, fins, streams, stats, rstats, dir_mentions }
}

/// Every non-shed request's incremental stream must equal its finished
/// output exactly — no token lost, duplicated, or re-sent on replay.
fn assert_streams_match(run: &AsyncRun) {
    for (id, output, finish) in &run.outs {
        let streamed =
            run.streams.get(id).cloned().unwrap_or_default();
        if *finish == Some(FinishReason::Shed) {
            assert!(streamed.is_empty(),
                    "shed request {id} streamed tokens");
        } else {
            assert_eq!(&streamed, output,
                       "request {id}: streamed tokens != final output");
        }
    }
}

#[test]
fn n2_worker_router_bit_identical_to_sync_and_bare() {
    // The stream-identity golden: same work through a bare core, the
    // synchronous router, and the 2-worker threaded router — three
    // identical sets of (id, output, finish) triples, and the threaded
    // router's incremental streams equal its finished outputs.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let sync = run_sync(
        vec![FakeCore::new(ecfg(bs), 128), FakeCore::new(ecfg(bs), 128)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    let run = run_async(
        vec![FakeCore::new(ecfg(bs), 128), FakeCore::new(ecfg(bs), 128)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(sync, bare, "sync router diverged from bare core");
    assert_eq!(run.outs, bare, "threaded router diverged from bare");
    assert_streams_match(&run);
    // placement happens at submit time on the caller's thread, so
    // round-robin over back-to-back submits is exact
    let routed: Vec<usize> =
        run.stats.iter().map(|s| s.requests_routed).collect();
    assert_eq!(routed, vec![3, 3]);
    assert_eq!(run.rstats.dead, 0);
    assert_eq!(run.rstats.replayed, 0);
    assert_eq!(run.rstats.shed, 0);
    for f in &run.fins {
        assert!(f.replica.is_some(), "finish without a placement");
    }
}

#[test]
fn replica_killed_mid_stream_replays_onto_survivor() {
    // Worker 0's core dies permanently on its second step — mid-stream,
    // while worker 1 keeps stepping on its own thread. Every request
    // must still finish, streams must stay bit-identical to the
    // fault-free run, and no token may be duplicated or re-sent.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let run = run_async(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: 2 }),
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: usize::MAX }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(run.outs, bare,
               "streams diverged across mid-stream replica death");
    assert_streams_match(&run);
    assert_eq!(run.rstats.dead, 1);
    assert_eq!(run.rstats.alive, 1);
    assert!(run.rstats.degraded);
    assert!(run.rstats.replayed >= 1,
            "death at step 2 must strand at least one in-flight \
             request");
    assert_eq!(run.rstats.replica_failed, 0);
    assert!(run.stats[0].health.is_dead());
    assert!(run.stats[1].health.is_alive());
    assert!(!run.dir_mentions[0],
            "dead replica still hinted in the directory");
    // the survivor ends up serving everything the victim dropped
    assert!(run.stats[1].requests_routed >= 3 + run.rstats.replayed);
}

#[test]
fn transient_brownout_recovers_on_worker_clock() {
    // Worker 0 browns out for two consecutive steps, then recovers.
    // The worker retries with backoff on its own thread; the front end
    // only mirrors the quarantine. No death, no replay, identical
    // streams.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let run = run_async(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::TransientThenRecover {
                                from: 2,
                                fails: 2,
                            }),
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: usize::MAX }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            max_step_retries: 10,
            retry_backoff_steps: 1,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(run.outs, bare, "brown-out changed a stream");
    assert_streams_match(&run);
    assert_eq!(run.rstats.dead, 0);
    assert_eq!(run.rstats.replayed, 0);
    assert_eq!(run.rstats.replica_failed, 0);
    for (_, _, finish) in &run.outs {
        assert_eq!(*finish, Some(FinishReason::MaxTokens));
    }
    for s in &run.stats {
        assert!(s.health.is_alive());
    }
}

/// Donor/blocker/rehit migration trace for the threaded front end:
/// warm replica 0 with a 32-token prefix, wait for the donor to
/// finish (so the directory is provably warm), then load replica 0
/// with a cold blocker and submit the warm rehit — the load penalty
/// outweighs the prefix hit, so the rehit places on cold replica 1 in
/// every arm. With `kv_migrate` the placement parks the rehit behind
/// an Export/Exported handshake with the donor's worker; the deferred
/// submit ships the blocks as `preload`.
fn run_warm_rehit<C>(cores: Vec<C>, kv_migrate: bool) -> AsyncRun
where
    C: ReplicaCore + Send + 'static,
{
    let mut router = AsyncRouter::new(cores, RouterConfig {
        routing: RoutingPolicy::CacheAware,
        load_penalty_tokens: 33,
        kv_migrate,
        ..Default::default()
    });
    let prefix: Vec<u32> = (0..32).map(|t| 7000 + t).collect();
    let mut donor = prefix.clone();
    donor.extend([9001, 9002]);
    router.submit(donor, sp(2));
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut polls = 0usize;
    while fins.is_empty() {
        polls += 1;
        assert!(polls < 3_000, "donor request did not finish");
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let blocker: Vec<u32> = (0..20).map(|t| 500 + t).collect();
    router.submit(blocker, sp(6));
    let mut warm = prefix;
    warm.extend([8001, 8002, 8003]);
    router.submit(warm, sp(3));
    while fins.len() < 3 {
        polls += 1;
        assert!(polls < 3_000,
                "migration run did not drain: {}/3 finished \
                 (a wedged handshake would hang here)",
                fins.len());
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let stats = router.stats();
    let rstats = router.router_stats();
    let dir_mentions = (0..stats.len())
        .map(|i| router.directory().mentions_replica(i))
        .collect();
    for ev in router.shutdown() {
        apply(ev, &mut streams, &mut fins);
    }
    let mut outs: Outs = fins
        .iter()
        .map(|f| (f.id, f.seq.output.clone(), f.seq.finish))
        .collect();
    outs.sort_by_key(|(id, _, _)| *id);
    AsyncRun { outs, fins, streams, stats, rstats, dir_mentions }
}

/// A pool-enabled fake core (adoption is refused with tiering off)
/// wrapped to be type-compatible with faulty peers.
fn pooled_stable(bs: usize) -> FaultyCore<FakeCore> {
    FaultyCore::new(
        FakeCore::new(
            EngineConfig { kv_pool_blocks: 16, ..ecfg(bs) },
            256,
        ),
        FaultSpec::FailOnStepK { k: usize::MAX },
    )
}

fn pooled_faulty(bs: usize, spec: FaultSpec) -> FaultyCore<FakeCore> {
    FaultyCore::new(
        FakeCore::new(
            EngineConfig { kv_pool_blocks: 16, ..ecfg(bs) },
            256,
        ),
        spec,
    )
}

#[test]
fn async_kv_migration_ships_warmth_and_off_is_inert() {
    // Tentpole e2e through the Export/Exported handshake: the rehit
    // parks while the donor's worker answers, then the deferred submit
    // preloads the receiver — identical streams to the migration-off
    // control, strictly fewer cold prefill tokens, counters on both
    // ends, no fallback.
    let bs = 4;
    let mig = run_warm_rehit(
        vec![pooled_stable(bs), pooled_stable(bs)], true);
    let ctl = run_warm_rehit(
        vec![pooled_stable(bs), pooled_stable(bs)], false);
    assert_eq!(mig.outs, ctl.outs, "migration changed a stream");
    assert_streams_match(&mig);
    assert_streams_match(&ctl);
    // the rehit (global id 2) served on the cold replica in both runs
    for run in [&mig, &ctl] {
        let f2 = run.fins.iter().find(|f| f.id == 2).unwrap();
        assert_eq!(f2.replica, Some(1),
                   "rehit was not forced off the warm replica");
        assert_eq!(f2.seq.output.len(), 3);
    }
    let exec = |r: &AsyncRun| -> usize {
        r.stats.iter()
            .map(|s| s.core.prefill_tokens_executed)
            .sum()
    };
    assert!(exec(&mig) < exec(&ctl),
            "migrated run executed {} !< control {}",
            exec(&mig), exec(&ctl));
    assert_eq!(mig.stats[0].core.kv_migrations_out, 8);
    assert_eq!(mig.stats[1].core.kv_migrations_in, 8);
    assert!(mig.stats[1].core.migrated_bytes > 0);
    assert_eq!(mig.rstats.migration_fallbacks, 0);
    assert_eq!(mig.rstats.dead, 0);
    for s in &ctl.stats {
        assert_eq!((s.core.kv_migrations_in, s.core.kv_migrations_out,
                    s.core.migrated_bytes), (0, 0, 0));
    }
    assert_eq!(ctl.rstats.migration_fallbacks, 0);
}

#[test]
fn async_migration_donor_death_midhandshake_falls_back() {
    let bs = 4;
    let ctl = run_warm_rehit(
        vec![pooled_stable(bs), pooled_stable(bs)], false);
    // transient export hiccup: Exported{failed} resolves the parked
    // rehit into a plain cold placement — no death, no quarantine,
    // identical streams, fallback counted exactly once (mig_tried
    // bounds migration to one attempt per request)
    let run = run_warm_rehit(
        vec![
            pooled_faulty(bs, FaultSpec::FailOnExport { transient: true }),
            pooled_stable(bs),
        ],
        true,
    );
    assert_eq!(run.outs, ctl.outs,
               "transient export fallback perturbed streams");
    assert_streams_match(&run);
    assert_eq!(run.rstats.migration_fallbacks, 1);
    assert_eq!(run.rstats.dead, 0);
    assert_eq!(run.rstats.replayed, 0);
    assert_eq!(run.stats[1].core.kv_migrations_in, 0);
    for s in &run.stats {
        assert!(s.health.is_alive());
    }
    // donor dies answering the export: the Dead event resolves the
    // parked rehit (fallback to the receiver, cold), replays the
    // blocker that was in flight on the donor, and nothing hangs —
    // every stream still bit-identical, no token lost or duplicated
    let run = run_warm_rehit(
        vec![
            pooled_faulty(bs,
                          FaultSpec::FailOnExport { transient: false }),
            pooled_stable(bs),
        ],
        true,
    );
    assert_eq!(run.outs, ctl.outs,
               "donor death mid-handshake corrupted a stream");
    assert_streams_match(&run);
    assert!(run.rstats.migration_fallbacks >= 1);
    assert_eq!(run.rstats.dead, 1, "permanent export must kill donor");
    // the blocker replays off the dead donor unless the worker raced
    // through its whole budget before the Export command landed; the
    // stream identity above already pins no-loss/no-duplication
    assert!(run.rstats.replayed <= 1);
    assert!(run.stats[0].health.is_dead());
    assert!(!run.dir_mentions[0],
            "dead donor still hinted in the directory");
    // the parked rehit was resolved by the Dead event onto the
    // survivor — a wedged handshake would have tripped the poll bound
    let f2 = run.fins.iter().find(|f| f.id == 2).unwrap();
    assert_eq!(f2.replica, Some(1));
    assert_eq!(f2.seq.output.len(), 3);
}

#[test]
fn async_migration_receiver_failure_reroutes_to_survivor() {
    // The receiver rejects the deferred (preloaded) submit and dies;
    // the rehit must reroute to the surviving donor — which holds the
    // warm prefix anyway — rather than hang on the resolved handshake.
    let bs = 4;
    let ctl = run_warm_rehit(
        vec![pooled_stable(bs), pooled_stable(bs)], false);
    let run = run_warm_rehit(
        vec![
            pooled_stable(bs),
            // replica 1's first core-level submit IS the deferred
            // migration submit (the blocker went to replica 0)
            pooled_faulty(bs, FaultSpec::FailOnSubmit { k: 1 }),
        ],
        true,
    );
    assert_eq!(run.outs, ctl.outs,
               "receiver death during import corrupted a stream");
    assert_streams_match(&run);
    assert_eq!(run.rstats.dead, 1);
    assert!(run.stats[1].health.is_dead());
    assert!(!run.dir_mentions[1]);
    let f2 = run.fins.iter().find(|f| f.id == 2).unwrap();
    assert_eq!(f2.replica, Some(0),
               "rehit did not reroute to the survivor");
    assert_eq!(f2.seq.output.len(), 3);
}

#[test]
fn admission_sheds_back_to_back_submits_deterministically() {
    // Admission control runs on the caller's thread against the front
    // end's own outstanding counts, which cannot change between
    // back-to-back submits — so exactly the first `max_waiting`
    // requests are admitted and the rest shed, every run.
    let bs = 4;
    let work = work_list();
    let mut router = AsyncRouter::new(
        vec![FakeCore::new(ecfg(bs), 128)],
        RouterConfig { max_waiting: 2, ..Default::default() },
    );
    let ids: Vec<u64> = work
        .iter()
        .map(|(p, max_new)| router.submit(p.clone(), sp(*max_new)))
        .collect();
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut polls = 0usize;
    while fins.len() < work.len() {
        polls += 1;
        assert!(polls < 3_000, "shed run did not drain");
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let rstats = router.router_stats();
    for ev in router.shutdown() {
        apply(ev, &mut streams, &mut fins);
    }
    fins.sort_by_key(|f| f.id);
    let shed_ids: Vec<u64> = fins
        .iter()
        .filter(|f| f.seq.finish == Some(FinishReason::Shed))
        .map(|f| f.id)
        .collect();
    assert_eq!(shed_ids, ids[2..].to_vec(),
               "shed set is not the deterministic tail");
    assert_eq!(rstats.shed, work.len() - 2);
    for f in &fins {
        if f.seq.finish == Some(FinishReason::Shed) {
            assert!(f.replica.is_none());
            assert!(f.seq.output.is_empty());
            assert!(!streams.contains_key(&f.id),
                    "shed request {} streamed tokens", f.id);
        } else {
            assert_eq!(f.seq.finish, Some(FinishReason::MaxTokens));
        }
    }
    // the two admitted requests generate exactly what a bare core
    // would for the same prompts
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work[..2]);
    for ((id, out), (_, bare_out, _)) in fins
        .iter()
        .filter(|f| f.seq.finish == Some(FinishReason::MaxTokens))
        .map(|f| (f.id, f.seq.output.clone()))
        .zip(bare)
    {
        assert_eq!(out, bare_out, "admitted request {id} diverged");
        assert_eq!(streams[&id], out);
    }
}
