//! Threaded front-end ([`AsyncRouter`]) properties over deterministic
//! fake cores — the async counterpart of `router_properties.rs`.
//!
//! The content-determined fake model makes exact stream goldens
//! feasible even though worker threads interleave nondeterministically:
//! any correct scheduling/batching/replay must produce bit-identical
//! per-request token streams. Locked down:
//!
//! * **stream-identity golden**: the N-worker threaded router produces
//!   the same `(id, output, finish)` triples as the synchronous
//!   [`Router`] and as a bare [`FakeCore`] on the same work, and every
//!   incrementally streamed token sequence equals the finished output
//!   (indices contiguous from 0);
//! * a replica **killed mid-stream** on its own worker thread loses no
//!   request and duplicates no token: in-flight work replays onto the
//!   survivor and streams stay bit-identical to the fault-free run,
//!   with the dead replica purged from the cache directory;
//! * a transient **brown-out recovers** on the worker's own
//!   retry/backoff clock without death or replay;
//! * **admission control sheds deterministically**: back-to-back
//!   submissions are judged against the front end's own outstanding
//!   counts, which cannot change between submits.

use std::collections::HashMap;
use std::time::Duration;

use sqplus::config::{EngineConfig, RouterConfig, RoutingPolicy};
use sqplus::coordinator::fake::FakeCore;
use sqplus::coordinator::fault::{FaultSpec, FaultyCore};
use sqplus::coordinator::replica::{ReplicaCore, ReplicaStats};
use sqplus::coordinator::router::{RoutedFinish, Router, RouterStats};
use sqplus::coordinator::sequence::{FinishReason, SamplingParams};
use sqplus::coordinator::worker::{AsyncRouter, RouterEvent};

fn ecfg(block_size: usize) -> EngineConfig {
    EngineConfig {
        max_running: 4,
        max_batch_tokens: 64,
        decode_batches: vec![1, 2, 4, 8],
        prefill_buckets: vec![(4, 64)],
        block_size,
        ..Default::default()
    }
}

fn sp(max_new: usize) -> SamplingParams {
    SamplingParams { max_new_tokens: max_new, ..Default::default() }
}

/// Deterministic work list: 6 unique prompts with mixed budgets.
fn work_list() -> Vec<(Vec<u32>, usize)> {
    (0..6u32)
        .map(|i| {
            let p: Vec<u32> = (0..(6 + i as usize % 5) as u32)
                .map(|t| 500 + i * 97 + t)
                .collect();
            (p, 2 + i as usize % 4)
        })
        .collect()
}

type Outs = Vec<(u64, Vec<u32>, Option<FinishReason>)>;

/// Drive a bare core over the work list; the reference streams.
fn run_bare(mut core: FakeCore, work: &[(Vec<u32>, usize)]) -> Outs {
    for (p, max_new) in work {
        core.submit(p.clone(), sp(*max_new)).unwrap();
    }
    let mut out: Outs = vec![];
    for _ in 0..10_000 {
        core.step().unwrap();
        for q in core.take_finished() {
            out.push((q.id, q.output.clone(), q.finish));
        }
        if !core.has_work() {
            break;
        }
    }
    assert!(!core.has_work(), "bare core did not drain");
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Drive the synchronous router over the same work.
fn run_sync(
    cores: Vec<FakeCore>,
    rcfg: RouterConfig,
    work: &[(Vec<u32>, usize)],
) -> Outs {
    let mut router = Router::new(cores, rcfg);
    for (p, max_new) in work {
        router.submit(p.clone(), sp(*max_new));
    }
    router.run_to_completion(10_000).unwrap();
    let mut out: Outs = router
        .take_finished()
        .into_iter()
        .map(|f| (f.id, f.seq.output, f.seq.finish))
        .collect();
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Everything a threaded run produced, for assertions after the fact.
struct AsyncRun {
    outs: Outs,
    fins: Vec<RoutedFinish>,
    /// Incrementally streamed tokens per request, in index order
    /// (contiguity is asserted as the events arrive).
    streams: HashMap<u64, Vec<u32>>,
    stats: Vec<ReplicaStats>,
    rstats: RouterStats,
    /// Whether the cache directory still hints at replica `i`
    /// (snapshot taken after the last request finished).
    dir_mentions: Vec<bool>,
}

fn apply(
    ev: RouterEvent,
    streams: &mut HashMap<u64, Vec<u32>>,
    fins: &mut Vec<RoutedFinish>,
) {
    match ev {
        RouterEvent::Token { id, index, token } => {
            let s = streams.entry(id).or_default();
            assert_eq!(index, s.len(),
                       "stream {id}: non-contiguous token index");
            s.push(token);
        }
        RouterEvent::Finished(f) => fins.push(f),
    }
}

/// Submit the whole work list back-to-back, poll to completion, then
/// shut down and fold in the final events.
fn run_async<C>(
    cores: Vec<C>,
    rcfg: RouterConfig,
    work: &[(Vec<u32>, usize)],
) -> AsyncRun
where
    C: ReplicaCore + Send + 'static,
{
    let mut router = AsyncRouter::new(cores, rcfg);
    for (p, max_new) in work {
        router.submit(p.clone(), sp(*max_new));
    }
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut polls = 0usize;
    while fins.len() < work.len() {
        polls += 1;
        assert!(polls < 3_000,
                "async router did not drain: {}/{} finished",
                fins.len(), work.len());
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let stats = router.stats();
    let rstats = router.router_stats();
    let dir_mentions = (0..stats.len())
        .map(|i| router.directory().mentions_replica(i))
        .collect();
    for ev in router.shutdown() {
        apply(ev, &mut streams, &mut fins);
    }
    let mut outs: Outs = fins
        .iter()
        .map(|f| (f.id, f.seq.output.clone(), f.seq.finish))
        .collect();
    outs.sort_by_key(|(id, _, _)| *id);
    AsyncRun { outs, fins, streams, stats, rstats, dir_mentions }
}

/// Every non-shed request's incremental stream must equal its finished
/// output exactly — no token lost, duplicated, or re-sent on replay.
fn assert_streams_match(run: &AsyncRun) {
    for (id, output, finish) in &run.outs {
        let streamed =
            run.streams.get(id).cloned().unwrap_or_default();
        if *finish == Some(FinishReason::Shed) {
            assert!(streamed.is_empty(),
                    "shed request {id} streamed tokens");
        } else {
            assert_eq!(&streamed, output,
                       "request {id}: streamed tokens != final output");
        }
    }
}

#[test]
fn n2_worker_router_bit_identical_to_sync_and_bare() {
    // The stream-identity golden: same work through a bare core, the
    // synchronous router, and the 2-worker threaded router — three
    // identical sets of (id, output, finish) triples, and the threaded
    // router's incremental streams equal its finished outputs.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let sync = run_sync(
        vec![FakeCore::new(ecfg(bs), 128), FakeCore::new(ecfg(bs), 128)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    let run = run_async(
        vec![FakeCore::new(ecfg(bs), 128), FakeCore::new(ecfg(bs), 128)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(sync, bare, "sync router diverged from bare core");
    assert_eq!(run.outs, bare, "threaded router diverged from bare");
    assert_streams_match(&run);
    // placement happens at submit time on the caller's thread, so
    // round-robin over back-to-back submits is exact
    let routed: Vec<usize> =
        run.stats.iter().map(|s| s.requests_routed).collect();
    assert_eq!(routed, vec![3, 3]);
    assert_eq!(run.rstats.dead, 0);
    assert_eq!(run.rstats.replayed, 0);
    assert_eq!(run.rstats.shed, 0);
    for f in &run.fins {
        assert!(f.replica.is_some(), "finish without a placement");
    }
}

#[test]
fn replica_killed_mid_stream_replays_onto_survivor() {
    // Worker 0's core dies permanently on its second step — mid-stream,
    // while worker 1 keeps stepping on its own thread. Every request
    // must still finish, streams must stay bit-identical to the
    // fault-free run, and no token may be duplicated or re-sent.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let run = run_async(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: 2 }),
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: usize::MAX }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(run.outs, bare,
               "streams diverged across mid-stream replica death");
    assert_streams_match(&run);
    assert_eq!(run.rstats.dead, 1);
    assert_eq!(run.rstats.alive, 1);
    assert!(run.rstats.degraded);
    assert!(run.rstats.replayed >= 1,
            "death at step 2 must strand at least one in-flight \
             request");
    assert_eq!(run.rstats.replica_failed, 0);
    assert!(run.stats[0].health.is_dead());
    assert!(run.stats[1].health.is_alive());
    assert!(!run.dir_mentions[0],
            "dead replica still hinted in the directory");
    // the survivor ends up serving everything the victim dropped
    assert!(run.stats[1].requests_routed >= 3 + run.rstats.replayed);
}

#[test]
fn transient_brownout_recovers_on_worker_clock() {
    // Worker 0 browns out for two consecutive steps, then recovers.
    // The worker retries with backoff on its own thread; the front end
    // only mirrors the quarantine. No death, no replay, identical
    // streams.
    let bs = 4;
    let work = work_list();
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work);
    let run = run_async(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::TransientThenRecover {
                                from: 2,
                                fails: 2,
                            }),
            FaultyCore::new(FakeCore::new(ecfg(bs), 128),
                            FaultSpec::FailOnStepK { k: usize::MAX }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            max_step_retries: 10,
            retry_backoff_steps: 1,
            ..Default::default()
        },
        &work,
    );
    assert_eq!(run.outs, bare, "brown-out changed a stream");
    assert_streams_match(&run);
    assert_eq!(run.rstats.dead, 0);
    assert_eq!(run.rstats.replayed, 0);
    assert_eq!(run.rstats.replica_failed, 0);
    for (_, _, finish) in &run.outs {
        assert_eq!(*finish, Some(FinishReason::MaxTokens));
    }
    for s in &run.stats {
        assert!(s.health.is_alive());
    }
}

#[test]
fn admission_sheds_back_to_back_submits_deterministically() {
    // Admission control runs on the caller's thread against the front
    // end's own outstanding counts, which cannot change between
    // back-to-back submits — so exactly the first `max_waiting`
    // requests are admitted and the rest shed, every run.
    let bs = 4;
    let work = work_list();
    let mut router = AsyncRouter::new(
        vec![FakeCore::new(ecfg(bs), 128)],
        RouterConfig { max_waiting: 2, ..Default::default() },
    );
    let ids: Vec<u64> = work
        .iter()
        .map(|(p, max_new)| router.submit(p.clone(), sp(*max_new)))
        .collect();
    let mut streams: HashMap<u64, Vec<u32>> = HashMap::new();
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut polls = 0usize;
    while fins.len() < work.len() {
        polls += 1;
        assert!(polls < 3_000, "shed run did not drain");
        for ev in router.poll(Duration::from_millis(10)) {
            apply(ev, &mut streams, &mut fins);
        }
    }
    let rstats = router.router_stats();
    for ev in router.shutdown() {
        apply(ev, &mut streams, &mut fins);
    }
    fins.sort_by_key(|f| f.id);
    let shed_ids: Vec<u64> = fins
        .iter()
        .filter(|f| f.seq.finish == Some(FinishReason::Shed))
        .map(|f| f.id)
        .collect();
    assert_eq!(shed_ids, ids[2..].to_vec(),
               "shed set is not the deterministic tail");
    assert_eq!(rstats.shed, work.len() - 2);
    for f in &fins {
        if f.seq.finish == Some(FinishReason::Shed) {
            assert!(f.replica.is_none());
            assert!(f.seq.output.is_empty());
            assert!(!streams.contains_key(&f.id),
                    "shed request {} streamed tokens", f.id);
        } else {
            assert_eq!(f.seq.finish, Some(FinishReason::MaxTokens));
        }
    }
    // the two admitted requests generate exactly what a bare core
    // would for the same prompts
    let bare = run_bare(FakeCore::new(ecfg(bs), 128), &work[..2]);
    for ((id, out), (_, bare_out, _)) in fins
        .iter()
        .filter(|f| f.seq.finish == Some(FinishReason::MaxTokens))
        .map(|f| (f.id, f.seq.output.clone()))
        .zip(bare)
    {
        assert_eq!(out, bare_out, "admitted request {id} diverged");
        assert_eq!(streams[&id], out);
    }
}
