//! Multi-replica router properties over a deterministic fake replica
//! core — pure scheduler + block-manager accounting with a
//! content-determined fake model, no PJRT runtime, so everything here
//! runs in tier-1 CI without artifacts (the `scheduler_properties.rs`
//! harness style extended to the router layer).
//!
//! Locked down:
//! * an N=1 router is *bit-identical* to driving the replica core
//!   directly (same submission schedule → same ids, streams, finish
//!   reasons);
//! * an N=2 router serves the same trace with the same per-request
//!   token streams as one core (the fake model is content-determined,
//!   so any correct routing/scheduling must agree);
//! * cache-aware routing sends a shared-prefix burst to the replica
//!   already holding the prefix and executes strictly fewer cold
//!   prefill tokens than round-robin on the same trace;
//! * the shared cache directory exactly mirrors every replica's own
//!   hash-chain lookups after each step (randomized);
//! * sliding-window eviction keeps every replica's
//!   cached-but-unreferenced block count at/below the high watermark
//!   for the whole run and never breaks block conservation
//!   (randomized);
//! * the `{"cmd":"stats"}` payload round-trips the per-replica rows;
//! * **fault tolerance** (via [`FaultyCore`]'s deterministic failure
//!   schedules): a replica crashing permanently mid-stream loses no
//!   request and duplicates no token — its in-flight load replays onto
//!   the survivor and every stream stays bit-identical to the
//!   fault-free run; transient failures quarantine with backoff and
//!   recover; exhausted retries escalate to Dead; failed submits
//!   reroute; admission control sheds over-budget load with the `shed`
//!   finish reason; and a randomized fault-injection sweep holds all
//!   of the recovery invariants at once — with and without the tiered
//!   KV demotion pool, where a killed replica's pool must come back
//!   empty (its demoted blocks can never be restored);
//! * **cross-replica KV migration**: a warm prefix forced onto a cold
//!   replica ships the donor's stashed blocks instead of recomputing
//!   them (strictly fewer cold prefill tokens, bit-identical streams
//!   and placements, counters on both ends), `--kv-migrate off` is
//!   inert, and a donor failing mid-migration — transiently or
//!   permanently — degrades to plain recompute without perturbing any
//!   stream.

use sqplus::config::{
    CacheWatermarks, EngineConfig, RouterConfig, RoutingPolicy,
};
use sqplus::coordinator::fake::FakeCore;
use sqplus::coordinator::fault::{FaultSpec, FaultyCore};
use sqplus::coordinator::replica::{
    ReplicaCore, ReplicaHealth, ReplicaStats,
};
use sqplus::coordinator::router::{RoutedFinish, Router};
use sqplus::coordinator::sequence::{FinishReason, SamplingParams};
use sqplus::util::json;
use sqplus::util::prop;
use sqplus::util::rng::Rng;

fn ecfg(block_size: usize) -> EngineConfig {
    EngineConfig {
        max_running: 4,
        max_batch_tokens: 64,
        decode_batches: vec![1, 2, 4, 8],
        prefill_buckets: vec![(4, 64)],
        block_size,
        ..Default::default()
    }
}

fn shared_prefixes(bs: usize) -> Vec<Vec<u32>> {
    (0..3u32)
        .map(|i| (0..(bs * (1 + i as usize)) as u32)
            .map(|t| i * 131 + t)
            .collect())
        .collect()
}

fn prompt(rng: &mut Rng, prefixes: &[Vec<u32>], uniq: u32) -> Vec<u32> {
    let mut p = prefixes[rng.below(prefixes.len())].clone();
    let extra = 1 + rng.below(12);
    p.extend((0..extra as u32).map(|t| 1000 + uniq * 31 + t));
    p
}

/// A never-failing fault wrapper — the control arm, type-compatible
/// with the faulty replicas in the same router.
fn stable(core: FakeCore) -> FaultyCore<FakeCore> {
    FaultyCore::new(core, FaultSpec::FailOnStepK { k: usize::MAX })
}

/// Deterministic submission schedule: request `i` is submitted before
/// step `3 * i`, with a per-request token budget. The same schedule is
/// replayable against a bare core or any router.
fn schedule(prompts: &[Vec<u32>]) -> Vec<(usize, Vec<u32>, usize)> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| (3 * i, p.clone(), 2 + i % 5))
        .collect()
}

/// Drive a bare core through the schedule; streams by submission id.
fn run_bare(mut core: FakeCore, sched: &[(usize, Vec<u32>, usize)])
    -> Vec<(u64, Vec<u32>, Option<FinishReason>)> {
    let mut out = vec![];
    let mut next = 0usize;
    for step in 0..10_000 {
        while next < sched.len() && sched[next].0 <= step {
            let (_, p, max_new) = &sched[next];
            core.submit(p.clone(), SamplingParams {
                max_new_tokens: *max_new,
                ..Default::default()
            })
            .unwrap();
            next += 1;
        }
        core.step().unwrap();
        for q in core.take_finished() {
            out.push((q.id, q.output.clone(), q.finish));
        }
        if next == sched.len() && !core.has_work() {
            break;
        }
    }
    assert!(!core.has_work(), "bare core did not drain");
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Drive a router through the same schedule; streams by global id.
/// Returns the router too, so tests can inspect post-run health,
/// directory, and stats state.
fn run_router<C: ReplicaCore>(
    mut router: Router<C>,
    sched: &[(usize, Vec<u32>, usize)],
) -> (
    Vec<(u64, Vec<u32>, Option<FinishReason>)>,
    Vec<RoutedFinish>,
    Router<C>,
) {
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut next = 0usize;
    for step in 0..10_000 {
        while next < sched.len() && sched[next].0 <= step {
            let (_, p, max_new) = &sched[next];
            router.submit(p.clone(), SamplingParams {
                max_new_tokens: *max_new,
                ..Default::default()
            });
            next += 1;
        }
        router.step().unwrap();
        fins.extend(router.take_finished());
        if next == sched.len() && !router.has_work() {
            break;
        }
    }
    assert!(!router.has_work(), "router did not drain");
    let mut out: Vec<(u64, Vec<u32>, Option<FinishReason>)> = fins
        .iter()
        .map(|f| (f.id, f.seq.output.clone(), f.seq.finish))
        .collect();
    out.sort_by_key(|(id, _, _)| *id);
    (out, fins, router)
}

#[test]
fn router_n1_bit_identical_to_bare_core() {
    // The golden identity: a router over one replica is a pass-through.
    // Same schedule → same global ids, same streams, same finish
    // reasons, every request served by replica 0.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0x1234);
    let prompts: Vec<Vec<u32>> =
        (0..16u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    let router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256)],
        RouterConfig::default(),
    );
    let (routed, fins, _) = run_router(router, &sched);
    assert_eq!(bare, routed, "N=1 router diverged from bare core");
    assert!(fins.iter().all(|f| f.replica == Some(0)));
    // local ids equal global ids through a single replica
    assert!(fins.iter().all(|f| f.id == f.seq.id));
}

#[test]
fn router_n2_streams_match_single_core() {
    // Acceptance golden: the same trace through one core and through an
    // N=2 router (all three policies) produces the same token stream
    // per request — routing changes *where* work runs, never *what* is
    // generated.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0xbeef);
    let prompts: Vec<Vec<u32>> =
        (0..18u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    for routing in [RoutingPolicy::CacheAware, RoutingPolicy::LeastLoaded,
                    RoutingPolicy::RoundRobin] {
        let router = Router::new(
            vec![FakeCore::new(ecfg(bs), 256),
                 FakeCore::new(ecfg(bs), 256)],
            RouterConfig { routing, ..Default::default() },
        );
        let (routed, fins, _) = run_router(router, &sched);
        assert_eq!(bare, routed,
                   "N=2 {} diverged from single core",
                   routing.as_str());
        // with round-robin both replicas must actually serve traffic
        if routing == RoutingPolicy::RoundRobin {
            assert!(fins.iter().any(|f| f.replica == Some(0)));
            assert!(fins.iter().any(|f| f.replica == Some(1)));
        }
    }
}

#[test]
fn replica_death_midstream_replays_without_token_loss() {
    // THE fault-tolerance acceptance golden: N=2 round-robin router;
    // replica 1 crashes permanently on its 2nd step — mid-stream for
    // the request it was decoding (one token already emitted). Every
    // submitted request still completes exactly once, every stream is
    // bit-identical to the fault-free bare-core run (no lost or
    // duplicated tokens across the replay), and the final stats report
    // exactly one dead replica with its in-flight count replayed.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0xdead);
    let prompts: Vec<Vec<u32>> =
        (0..14u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    let router = Router::new(
        vec![
            stable(FakeCore::new(ecfg(bs), 256)),
            FaultyCore::new(FakeCore::new(ecfg(bs), 256),
                            FaultSpec::FailOnStepK { k: 2 }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
    );
    let (routed, fins, router) = run_router(router, &sched);
    // no request lost, none answered twice
    let mut ids: Vec<u64> = fins.iter().map(|f| f.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), sched.len(), "lost or duplicated requests");
    // streams bit-identical to the fault-free run — replays continued
    // exactly where the dead replica stopped
    assert_eq!(bare, routed, "streams diverged across a replica death");
    // exactly one dead replica, its in-flight load replayed
    let rs = router.router_stats();
    assert_eq!((rs.alive, rs.dead), (1, 1));
    assert!(rs.degraded, "1-of-2 alive must surface as degraded");
    assert!(rs.replayed > 0, "death happened with nothing in flight");
    assert_eq!(router.replicas()[1].replayed_out, rs.replayed);
    assert!(router.replicas()[1].health.is_dead());
    assert_eq!(rs.shed, 0);
    assert_eq!(rs.replica_failed, 0);
    // routing never scores the dead replica's cache again
    assert!(!router.directory().mentions_replica(1));
    // the mid-stream victim (request 1, round-robin's second pick)
    // finished on the survivor with its full budget honored
    let f1 = fins.iter().find(|f| f.id == 1).unwrap();
    assert_eq!(f1.replica, Some(0));
    assert_eq!(f1.seq.output.len(), sched[1].2);
}

#[test]
fn transient_failures_quarantine_then_recover() {
    // A brown-out (2 consecutive transient step failures) quarantines
    // the replica with backoff; the retry succeeds, the replica
    // returns to Healthy, nothing dies, and no stream is perturbed.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0x7777);
    let prompts: Vec<Vec<u32>> =
        (0..10u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    let router = Router::new(
        vec![
            stable(FakeCore::new(ecfg(bs), 256)),
            FaultyCore::new(
                FakeCore::new(ecfg(bs), 256),
                FaultSpec::TransientThenRecover { from: 2, fails: 2 },
            ),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            max_step_retries: 3,
            retry_backoff_steps: 1,
            ..Default::default()
        },
    );
    let (routed, _, router) = run_router(router, &sched);
    assert_eq!(bare, routed, "brown-out perturbed the streams");
    let rs = router.router_stats();
    assert_eq!(rs.dead, 0, "a recoverable brown-out must not kill");
    assert_eq!(rs.replayed, 0);
    assert!(rs.retries >= 1, "quarantine retries were never counted");
    assert!(!rs.degraded);
    assert!(router
        .replicas()
        .iter()
        .all(|r| r.health == ReplicaHealth::Healthy));
}

#[test]
fn exhausted_retries_escalate_to_dead() {
    // A replica failing transiently on *every* step exhausts the retry
    // budget and is killed — with its in-flight load replayed, so the
    // trace still completes bit-identically.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0x5150);
    let prompts: Vec<Vec<u32>> =
        (0..10u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    let router = Router::new(
        vec![
            stable(FakeCore::new(ecfg(bs), 256)),
            FaultyCore::new(FakeCore::new(ecfg(bs), 256),
                            FaultSpec::FailEveryN { n: 1 }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            max_step_retries: 1,
            retry_backoff_steps: 1,
            ..Default::default()
        },
    );
    let (routed, _, router) = run_router(router, &sched);
    assert_eq!(bare, routed);
    let rs = router.router_stats();
    assert_eq!(rs.dead, 1, "exhausted retries must escalate to Dead");
    assert!(rs.retries >= 1);
    assert!(rs.replayed >= 1, "the stuck replica's queue must replay");
    assert!(router.replicas()[1].health.is_dead());
    assert!(!router.directory().mentions_replica(1));
}

#[test]
fn submit_failure_reroutes_to_survivor() {
    let bs = 4;
    let p: Vec<u32> = (0..10).collect();
    let params = SamplingParams {
        max_new_tokens: 3,
        ..Default::default()
    };
    // round-robin picks replica 0 first; its submit fails permanently,
    // so it is killed and the request lands on replica 1 instead
    let mut router = Router::new(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(bs), 256),
                            FaultSpec::FailOnSubmit { k: 1 }),
            stable(FakeCore::new(ecfg(bs), 256)),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
    );
    router.submit(p.clone(), params.clone());
    router.run_to_completion(1000).unwrap();
    let fins = router.take_finished();
    assert_eq!(fins.len(), 1);
    assert_eq!(fins[0].replica, Some(1));
    assert!(matches!(fins[0].seq.finish,
                     Some(FinishReason::MaxTokens)));
    let rs = router.router_stats();
    assert_eq!(rs.dead, 1);
    assert!(rs.retries >= 1, "a failed submit is a counted retry");
    assert_eq!(rs.replica_failed, 0);

    // ...and with no survivor at all, the request fails cleanly with
    // `replica_failed` instead of hanging a client forever
    let mut router = Router::new(
        vec![FaultyCore::new(FakeCore::new(ecfg(bs), 256),
                             FaultSpec::FailOnSubmit { k: 1 })],
        RouterConfig::default(),
    );
    let id = router.submit(p, params);
    let fins = router.take_finished();
    assert_eq!(fins.len(), 1);
    assert_eq!(fins[0].id, id);
    assert_eq!(fins[0].replica, None);
    assert!(matches!(fins[0].seq.finish,
                     Some(FinishReason::ReplicaFailed)));
    assert_eq!(router.router_stats().replica_failed, 1);
}

#[test]
fn admission_control_sheds_over_budget_load() {
    let bs = 4;
    let p: Vec<u32> = (0..8).collect();
    let params = SamplingParams {
        max_new_tokens: 2,
        ..Default::default()
    };
    // global waiting budget: the third submission (2 already waiting)
    // sheds immediately — empty output, no replica, `shed` finish
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256)],
        RouterConfig { max_waiting: 2, ..Default::default() },
    );
    for _ in 0..3 {
        router.submit(p.clone(), params.clone());
    }
    let fins = router.take_finished();
    assert_eq!(fins.len(), 1, "third submission must shed");
    assert_eq!(fins[0].id, 2);
    assert_eq!(fins[0].replica, None);
    assert!(matches!(fins[0].seq.finish, Some(FinishReason::Shed)));
    assert!(fins[0].seq.output.is_empty());
    assert_eq!(router.router_stats().shed, 1);
    // the two admitted requests still complete normally
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 2);
    assert_eq!(router.router_stats().shed, 1);

    // per-replica queue cap: submissions spread across under-cap
    // replicas first, and shed only once *every* replica is full
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256),
             FakeCore::new(ecfg(bs), 256)],
        RouterConfig {
            routing: RoutingPolicy::LeastLoaded,
            max_replica_queue: 1,
            ..Default::default()
        },
    );
    for _ in 0..3 {
        router.submit(p.clone(), params.clone());
    }
    let fins = router.take_finished();
    assert_eq!(fins.len(), 1);
    assert!(matches!(fins[0].seq.finish, Some(FinishReason::Shed)));
    let routed: Vec<usize> = router
        .replicas()
        .iter()
        .map(|r| r.requests_routed)
        .collect();
    assert_eq!(routed, vec![1, 1], "cap must spread before shedding");
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 2);
    assert_eq!(router.router_stats().shed, 1);
}

#[test]
fn randomized_fault_injection_preserves_every_request() {
    // Randomized recovery-invariant sweep: N replicas, one random
    // victim crashing permanently at a random step. Invariants:
    // (a) every submitted request finishes exactly once — none lost,
    //     none answered twice;
    // (b) every stream is bit-identical to the fault-free run (the
    //     fake model is content-determined, so a correct replay *must*
    //     continue exactly where the victim stopped);
    // (c) a dead victim's directory entries are purged, its replay
    //     count is coherent, and nothing was shed or dropped;
    // (d) with the tiered KV pool on, every replica's pool occupancy
    //     stays within its bound and a *killed* replica's pool is
    //     empty — its demoted blocks can never be restored.
    prop::check("fault sweep", 6, |rng| {
        let bs = 2 + rng.below(3);
        let prefixes = shared_prefixes(bs);
        let n_req = 8 + rng.below(8);
        let prompts: Vec<Vec<u32>> = (0..n_req)
            .map(|i| prompt(rng, &prefixes, i as u32))
            .collect();
        let sched = schedule(&prompts);
        let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
        let n = 2 + rng.below(2);
        let victim = rng.below(n);
        let k = 1 + rng.below(12);
        // small device pools force evictions, so the tiering arm
        // actually demotes; the untiered arm is the original sweep
        let blocks = 24 + rng.below(32);
        for pool in [0usize, 4 + rng.below(8)] {
            let cores: Vec<FaultyCore<FakeCore>> = (0..n)
                .map(|i| {
                    let core = FakeCore::new(
                        EngineConfig {
                            kv_pool_blocks: pool,
                            ..ecfg(bs)
                        },
                        blocks,
                    );
                    if i == victim {
                        FaultyCore::new(core,
                                        FaultSpec::FailOnStepK { k })
                    } else {
                        stable(core)
                    }
                })
                .collect();
            let router = Router::new(cores, RouterConfig {
                routing: RoutingPolicy::CacheAware,
                ..Default::default()
            });
            let (routed, fins, router) = run_router(router, &sched);
            // (a)
            let mut ids: Vec<u64> = fins.iter().map(|f| f.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), sched.len(),
                       "lost or duplicated requests");
            // (b)
            assert_eq!(bare, routed,
                       "streams diverged under fault injection");
            // (c)
            let rs = router.router_stats();
            let dead = router
                .replicas()
                .iter()
                .filter(|r| r.health.is_dead())
                .count();
            assert_eq!(dead, rs.dead);
            assert!(rs.dead <= 1, "only the victim may die");
            if router.replicas()[victim].health.is_dead() {
                assert!(!router.directory().mentions_replica(victim),
                        "dead replica still hinted in the directory");
                assert_eq!(rs.replayed,
                           router.replicas()[victim].replayed_out);
            } else {
                // the victim was never stepped enough times to fire
                assert_eq!(rs.replayed, 0);
            }
            assert_eq!(rs.shed, 0);
            assert_eq!(rs.replica_failed, 0);
            // (d)
            for (i, r) in router.replicas().iter().enumerate() {
                let bm = &r.core().inner().sched.bm;
                assert!(bm.kv_pool_len() <= pool,
                        "replica {i} pool over bound");
                assert!(bm.check_conservation());
                if r.health.is_dead() {
                    assert_eq!(bm.kv_pool_len(), 0,
                               "killed replica {i} kept demoted \
                                blocks restorable");
                }
            }
        }
    });
}

/// Shared-prefix burst trace: a donor request warms one replica's
/// cache, then `burst` requests share its prefix. Returns (total
/// prefill tokens executed, per-replica routed counts, streams).
fn run_burst(routing: RoutingPolicy)
    -> (usize, Vec<usize>, Vec<(u64, Vec<u32>)>) {
    let bs = 4;
    let prefix: Vec<u32> = (0..32).map(|t| 7000 + t).collect();
    let router_cfg = RouterConfig {
        routing,
        // 1 token per queued request: affinity dominates until a
        // replica's backlog outweighs the whole prefix
        load_penalty_tokens: 1,
        ..Default::default()
    };
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256), FakeCore::new(ecfg(bs), 256)],
        router_cfg,
    );
    // donor: prefix + 2 unique tokens; run to completion so its blocks
    // are registered and the directory is warm
    let mut donor = prefix.clone();
    donor.extend([9001, 9002]);
    router.submit(donor, SamplingParams {
        max_new_tokens: 2,
        ..Default::default()
    });
    router.run_to_completion(1000).unwrap();
    let mut fins = router.take_finished();
    // burst: 6 warm prompts, submitted together before any step
    for i in 0..6u32 {
        let mut p = prefix.clone();
        p.extend((0..3u32).map(|t| 8000 + i * 31 + t));
        router.submit(p, SamplingParams {
            max_new_tokens: 3,
            ..Default::default()
        });
    }
    router.run_to_completion(1000).unwrap();
    fins.extend(router.take_finished());
    let executed: usize = router
        .replicas()
        .iter()
        .map(|r| r.core().core_stats().prefill_tokens_executed)
        .sum();
    let routed: Vec<usize> = router
        .replicas()
        .iter()
        .map(|r| r.requests_routed)
        .collect();
    let mut streams: Vec<(u64, Vec<u32>)> = fins
        .into_iter()
        .map(|f| (f.id, f.seq.output))
        .collect();
    streams.sort_by_key(|(id, _)| *id);
    (executed, routed, streams)
}

#[test]
fn cache_aware_burst_lands_on_warm_replica() {
    let (ca_exec, ca_routed, ca_streams) =
        run_burst(RoutingPolicy::CacheAware);
    let (rr_exec, rr_routed, rr_streams) =
        run_burst(RoutingPolicy::RoundRobin);
    // identical generations either way (content-determined model)
    assert_eq!(ca_streams, rr_streams);
    // cache-aware: donor and the whole burst on replica 0
    assert_eq!(ca_routed, vec![7, 0],
               "burst did not follow the warm prefix");
    // round-robin sprays the burst across both replicas
    assert_eq!(rr_routed, vec![4, 3]);
    // the headline: strictly fewer cold prefill tokens executed
    assert!(ca_exec < rr_exec,
            "cache-aware executed {ca_exec} !< round-robin {rr_exec}");
}

#[test]
fn least_loaded_balances_a_cold_burst() {
    // with no cache hints and equal loads, least-loaded alternates via
    // the queue-depth signal instead of starving one replica
    let bs = 4;
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256), FakeCore::new(ecfg(bs), 256)],
        RouterConfig {
            routing: RoutingPolicy::LeastLoaded,
            ..Default::default()
        },
    );
    for i in 0..8u32 {
        let p: Vec<u32> =
            (0..10u32).map(|t| 100 + i * 97 + t).collect();
        router.submit(p, SamplingParams {
            max_new_tokens: 2,
            ..Default::default()
        });
    }
    let routed: Vec<usize> = router
        .replicas()
        .iter()
        .map(|r| r.requests_routed)
        .collect();
    assert_eq!(routed, vec![4, 4], "cold burst not balanced");
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 8);
}

#[test]
fn cache_spread_limit_unsticks_a_cold_replica() {
    // ROADMAP debt: pure cache affinity pins a single-hot-prefix
    // workload to the warm replica forever (the pinned `vec![7, 0]`
    // assertion above). `cache_spread_limit: k` caps consecutive
    // placements on one replica at k, so a cold replica is guaranteed
    // work at least every k+1 placements — without changing what any
    // request generates.
    prop::check("cache spread", 6, |rng| {
        let bs = 4;
        let prefix: Vec<u32> = (0..32).map(|t| 7000 + t).collect();
        let burst = 5 + rng.below(8);
        let spread = 1 + rng.below(3);
        let run = |spread_limit: usize| {
            let mut router = Router::new(
                vec![
                    FakeCore::new(ecfg(bs), 256),
                    FakeCore::new(ecfg(bs), 256),
                ],
                RouterConfig {
                    routing: RoutingPolicy::CacheAware,
                    // no load penalty: affinity alone decides, so
                    // only the spread cap can move work off replica 0
                    load_penalty_tokens: 0,
                    cache_spread_limit: spread_limit,
                    ..Default::default()
                },
            );
            // donor warms replica 0's cache with the shared prefix
            let mut donor = prefix.clone();
            donor.extend([9001, 9002]);
            router.submit(donor, SamplingParams {
                max_new_tokens: 2,
                ..Default::default()
            });
            router.run_to_completion(1000).unwrap();
            let mut fins = router.take_finished();
            // every burst request shares the hot prefix; submitted
            // back-to-back so placement sees a warm directory only
            // for replica 0
            for i in 0..burst as u32 {
                let mut p = prefix.clone();
                p.extend((0..2u32).map(|t| 8000 + i * 31 + t));
                router.submit(p, SamplingParams {
                    max_new_tokens: 3,
                    ..Default::default()
                });
            }
            router.run_to_completion(2000).unwrap();
            fins.extend(router.take_finished());
            let routed: Vec<usize> = router
                .replicas()
                .iter()
                .map(|r| r.requests_routed)
                .collect();
            let mut streams: Vec<(u64, Vec<u32>)> = fins
                .into_iter()
                .map(|f| (f.id, f.seq.output))
                .collect();
            streams.sort_by_key(|(id, _)| *id);
            (routed, streams)
        };
        // control arm: with the cap off (default), affinity starves
        // the cold replica outright
        let (pinned, base_streams) = run(0);
        assert_eq!(pinned[1], 0, "control arm was not pinned");
        assert_eq!(pinned[0], burst + 1);
        let (spreaded, spread_streams) = run(spread);
        // the cold replica eventually receives work...
        assert!(spreaded[1] > 0,
                "cold replica starved despite spread limit {spread}: \
                 {spreaded:?}");
        // ...at the guaranteed cadence of one per k+1 placements...
        assert!(spreaded[1] >= burst / (spread + 1),
                "spread limit {spread} too weak: {spreaded:?} for \
                 burst {burst}");
        assert_eq!(spreaded[0] + spreaded[1], burst + 1);
        // ...and generations are byte-identical (content-determined
        // model): spreading is a placement policy, not a semantics
        // change
        assert_eq!(spread_streams, base_streams);
    });
}

#[test]
fn directory_mirrors_replica_caches_randomized() {
    // After every router step the shared directory must answer prefix
    // probes exactly as each replica's own block manager would — the
    // O(1)-routing contract: hints are drained-in-order events, so
    // post-step they are in sync (mid-step staleness is unobservable
    // from the routing path).
    prop::check("directory sync", 6, |rng| {
        let bs = 2 + rng.below(4);
        let prefixes = shared_prefixes(bs);
        let n = 2 + rng.below(2);
        let cores: Vec<FakeCore> = (0..n)
            .map(|_| FakeCore::new(ecfg(bs), 24 + rng.below(48)))
            .collect();
        let mut router = Router::new(cores, RouterConfig {
            routing: RoutingPolicy::CacheAware,
            // small sliding window so evictions happen and must be
            // reflected in the directory too
            watermarks: CacheWatermarks::new(4, 2),
            ..Default::default()
        });
        let mut submitted = 0usize;
        for _ in 0..300 {
            if submitted < 24 && rng.below(2) == 0 {
                let p = prompt(rng, &prefixes, submitted as u32);
                router.submit(p, SamplingParams {
                    max_new_tokens: 1 + rng.below(6),
                    ..Default::default()
                });
                submitted += 1;
            }
            router.step().unwrap();
            router.take_finished();
            // probe with every shared prefix extended past its end (a
            // lookup never covers the whole query) and a random one
            for pre in &prefixes {
                let mut probe = pre.clone();
                probe.extend([999_999, 999_998]);
                let dir_hits = router.directory().prefix_hits(
                    &probe, bs, router.replicas().len(),
                );
                for (i, r) in router.replicas().iter().enumerate() {
                    let bm_hit = r
                        .core()
                        .sched
                        .bm
                        .cached_prefix_tokens(&probe);
                    assert_eq!(
                        dir_hits[i], bm_hit,
                        "directory diverged from replica {i}"
                    );
                }
            }
            if submitted == 24 && !router.has_work() {
                break;
            }
        }
        assert!(!router.has_work(), "workload did not drain");
    });
}

#[test]
fn sliding_window_bounds_every_replica_for_whole_run() {
    // Acceptance: with watermarks configured through the router, no
    // replica's cached-but-unreferenced population ever exceeds the
    // high watermark, conservation holds throughout, and the pool
    // drains to fully free at the end.
    prop::check("router sliding window", 6, |rng| {
        let bs = 2 + rng.below(3);
        let prefixes = shared_prefixes(bs);
        let high = 2 + rng.below(4);
        let low = rng.below(high + 1);
        let mut router = Router::new(
            vec![
                FakeCore::new(ecfg(bs), 32 + rng.below(32)),
                FakeCore::new(ecfg(bs), 32 + rng.below(32)),
            ],
            RouterConfig {
                routing: RoutingPolicy::CacheAware,
                watermarks: CacheWatermarks::new(high, low),
                ..Default::default()
            },
        );
        let mut submitted = 0usize;
        let mut finished = 0usize;
        for _ in 0..600 {
            if submitted < 30 && rng.below(2) == 0 {
                let p = prompt(rng, &prefixes, submitted as u32);
                router.submit(p, SamplingParams {
                    max_new_tokens: 1 + rng.below(5),
                    ..Default::default()
                });
                submitted += 1;
            }
            router.step().unwrap();
            finished += router.take_finished().len();
            for r in router.replicas() {
                let bm = &r.core().sched.bm;
                assert!(bm.cached_unreferenced() <= high,
                        "window exceeded: {} > {high}",
                        bm.cached_unreferenced());
                assert!(bm.check_conservation(), "conservation broken");
            }
            if submitted == 30 && !router.has_work() {
                break;
            }
        }
        assert!(!router.has_work(), "workload did not drain");
        assert_eq!(finished, submitted);
        for r in router.replicas() {
            let bm = &r.core().sched.bm;
            assert_eq!(bm.free_blocks(), bm.total_blocks,
                       "pool did not drain to free");
        }
    });
}

/// Donor/blocker/rehit migration trace shared by the migration tests.
/// Replica 0 is warmed with a 32-token prefix, then loaded with a cold
/// blocker; the load penalty outweighs the whole prefix hit, so the
/// warm rehit places on cold replica 1 in *every* arm — migration on
/// or off, donor faulty or not — and the arms differ only in how the
/// receiver warms up. Streams are `(global id, replica, output)`.
fn run_migration<C: ReplicaCore>(cores: Vec<C>, kv_migrate: bool)
    -> (Vec<(u64, Option<usize>, Vec<u32>)>, Router<C>) {
    let mut router = Router::new(cores, RouterConfig {
        routing: RoutingPolicy::CacheAware,
        load_penalty_tokens: 33,
        kv_migrate,
        ..Default::default()
    });
    let prefix: Vec<u32> = (0..32).map(|t| 7000 + t).collect();
    let mut donor = prefix.clone();
    donor.extend([9001, 9002]);
    router.submit(donor, SamplingParams {
        max_new_tokens: 2,
        ..Default::default()
    });
    router.run_to_completion(1000).unwrap();
    let mut fins = router.take_finished();
    let blocker: Vec<u32> = (0..20).map(|t| 500 + t).collect();
    router.submit(blocker, SamplingParams {
        max_new_tokens: 6,
        ..Default::default()
    });
    let mut warm = prefix;
    warm.extend([8001, 8002, 8003]);
    router.submit(warm, SamplingParams {
        max_new_tokens: 3,
        ..Default::default()
    });
    router.run_to_completion(1000).unwrap();
    fins.extend(router.take_finished());
    let mut streams: Vec<(u64, Option<usize>, Vec<u32>)> = fins
        .into_iter()
        .map(|f| (f.id, f.replica, f.seq.output))
        .collect();
    streams.sort_by_key(|(id, _, _)| *id);
    (streams, router)
}

/// A [`FakeCore`] with the tiered pool on, so it can adopt migrated
/// blocks (adoption is refused with tiering off).
fn pooled(bs: usize) -> FakeCore {
    FakeCore::new(EngineConfig { kv_pool_blocks: 16, ..ecfg(bs) }, 256)
}

#[test]
fn kv_migration_ships_warmth_to_the_cold_replica() {
    // Tentpole e2e over the fake core: the warm rehit is forced onto
    // the cold replica; with `kv_migrate` the donor's 8 prefix blocks
    // (32 tokens, bs=4) ship over and the receiver restores them at
    // admission, so strictly fewer cold prefill tokens execute — with
    // placements and token streams bit-identical to the control run.
    let bs = 4;
    let (mig, mrouter) =
        run_migration(vec![pooled(bs), pooled(bs)], true);
    let (ctl, crouter) =
        run_migration(vec![pooled(bs), pooled(bs)], false);
    assert_eq!(mig, ctl,
               "migration changed a stream or a placement");
    assert_eq!(mig[2].1, Some(1),
               "rehit was not forced off the warm replica: {mig:?}");
    let exec = |rows: &[ReplicaStats]| -> usize {
        rows.iter().map(|s| s.core.prefill_tokens_executed).sum()
    };
    let (ms, cs) = (mrouter.stats(), crouter.stats());
    assert!(exec(&ms) < exec(&cs),
            "migrated run executed {} !< control {}",
            exec(&ms), exec(&cs));
    assert_eq!(ms[0].core.kv_migrations_out, 8);
    assert_eq!(ms[1].core.kv_migrations_in, 8);
    assert!(ms[1].core.migrated_bytes > 0);
    assert!(ms[1].core.recompute_avoided_tokens >= 32,
            "adopted blocks were not restored at admission");
    assert_eq!(mrouter.router_stats().migration_fallbacks, 0);
    // `--kv-migrate off` is inert: bit-identical behavior (asserted
    // above) and no migration counter moves anywhere
    for s in &cs {
        assert_eq!((s.core.kv_migrations_in, s.core.kv_migrations_out,
                    s.core.migrated_bytes), (0, 0, 0));
    }
    assert_eq!(crouter.router_stats().migration_fallbacks, 0);
}

#[test]
fn migration_donor_failure_degrades_to_recompute() {
    let bs = 4;
    let (ctl, _) = run_migration(
        vec![stable(pooled(bs)), stable(pooled(bs))], false);
    // transient export hiccup: fall back to plain recompute. The donor
    // is not punished — the optimization failed, not the replica — and
    // streams and placements are untouched.
    let (mig, router) = run_migration(
        vec![
            FaultyCore::new(pooled(bs),
                            FaultSpec::FailOnExport { transient: true }),
            stable(pooled(bs)),
        ],
        true,
    );
    assert_eq!(mig, ctl, "transient export fallback perturbed streams");
    let rs = router.router_stats();
    assert!(rs.migration_fallbacks >= 1, "fallback was not counted");
    assert_eq!(rs.dead, 0);
    assert!(router
        .replicas()
        .iter()
        .all(|r| r.health == ReplicaHealth::Healthy),
        "a failed optimization must not quarantine the donor");
    for s in router.stats() {
        assert_eq!(s.core.kv_migrations_in, 0);
    }
    // permanent export failure: the donor dies mid-migration. The
    // rehit still completes by recompute on the receiver, the donor's
    // in-flight blocker replays onto the survivor, and no token is
    // lost or duplicated.
    let (mig, router) = run_migration(
        vec![
            FaultyCore::new(pooled(bs),
                            FaultSpec::FailOnExport { transient: false }),
            stable(pooled(bs)),
        ],
        true,
    );
    // placements move (everything ends on the survivor), streams don't
    let strip = |v: &[(u64, Option<usize>, Vec<u32>)]| {
        v.iter().map(|(id, _, out)| (*id, out.clone()))
            .collect::<Vec<_>>()
    };
    assert_eq!(strip(&mig), strip(&ctl),
               "donor death mid-migration corrupted a stream");
    assert!(mig.iter().all(|(_, r, _)| *r == Some(1)));
    let rs = router.router_stats();
    assert!(rs.migration_fallbacks >= 1);
    assert_eq!(rs.dead, 1, "permanent export must kill the donor");
    assert_eq!(rs.replayed, 1, "the blocker must replay off the donor");
    assert!(router.replicas()[0].health.is_dead());
    assert!(!router.directory().mentions_replica(0));
    assert_eq!(rs.shed, 0);
    assert_eq!(rs.replica_failed, 0);
}

#[test]
fn stats_rows_roundtrip_through_wire_json() {
    // End-to-end stats check against live rows: submit traffic, step,
    // snapshot, serialize with the server's encoder, parse back — and
    // strict-decode back into typed rows.
    let bs = 4;
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 64), FakeCore::new(ecfg(bs), 64)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
    );
    for i in 0..4u32 {
        let p: Vec<u32> = (0..12u32).map(|t| i * 131 + t + 1).collect();
        router.submit(p, SamplingParams {
            max_new_tokens: 4,
            ..Default::default()
        });
    }
    for _ in 0..3 {
        router.step().unwrap();
    }
    let rows = router.stats();
    let rstats = router.router_stats();
    let v = json::parse(
        &sqplus::server::stats_json(&rows, &rstats).to_string(),
    )
    .unwrap();
    let reps = v.get("replicas").as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    for (i, rep) in reps.iter().enumerate() {
        assert_eq!(rep.get("id").as_usize(), Some(i));
        assert_eq!(rep.get("requests_routed").as_usize(),
                   Some(rows[i].requests_routed));
        assert_eq!(rep.get("health").as_str(), Some("healthy"));
        assert_eq!(rep.get("waiting").as_usize(),
                   Some(rows[i].core.waiting));
        assert_eq!(rep.get("running").as_usize(),
                   Some(rows[i].core.running));
        assert_eq!(rep.get("prefill_tokens_executed").as_usize(),
                   Some(rows[i].core.prefill_tokens_executed));
    }
    assert_eq!(v.get("router").get("alive").as_usize(), Some(2));
    assert_eq!(v.get("router").get("degraded").as_bool(), Some(false));
    let (drows, drouter) = sqplus::server::decode_stats(&v).unwrap();
    assert_eq!(drows.len(), 2);
    assert_eq!(drouter, rstats);
    assert_eq!(rows[0].requests_routed + rows[1].requests_routed, 4);
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 4);
}
