//! Multi-replica router properties over a deterministic fake replica
//! core — pure scheduler + block-manager accounting with a
//! content-determined fake model, no PJRT runtime, so everything here
//! runs in tier-1 CI without artifacts (the `scheduler_properties.rs`
//! harness style extended to the router layer).
//!
//! Locked down:
//! * an N=1 router is *bit-identical* to driving the replica core
//!   directly (same submission schedule → same ids, streams, finish
//!   reasons);
//! * an N=2 router serves the same trace with the same per-request
//!   token streams as one core (the fake model is content-determined,
//!   so any correct routing/scheduling must agree);
//! * cache-aware routing sends a shared-prefix burst to the replica
//!   already holding the prefix and executes strictly fewer cold
//!   prefill tokens than round-robin on the same trace;
//! * the shared cache directory exactly mirrors every replica's own
//!   hash-chain lookups after each step (randomized);
//! * sliding-window eviction keeps every replica's
//!   cached-but-unreferenced block count at/below the high watermark
//!   for the whole run and never breaks block conservation
//!   (randomized);
//! * the `{"cmd":"stats"}` payload round-trips the per-replica rows.

use std::collections::HashMap;

use anyhow::Result;

use sqplus::config::{
    CacheWatermarks, EngineConfig, RouterConfig, RoutingPolicy,
};
use sqplus::coordinator::block_manager::{BlockManager, CacheEvent};
use sqplus::coordinator::replica::{CoreStats, ReplicaCore};
use sqplus::coordinator::router::{RoutedFinish, Router};
use sqplus::coordinator::scheduler::Scheduler;
use sqplus::coordinator::sequence::{
    FinishReason, SamplingParams, SeqState, Sequence,
};
use sqplus::util::json;
use sqplus::util::prop;
use sqplus::util::rng::Rng;

/// Deterministic fake model: the next token is a pure function of the
/// content so far — so token streams cannot depend on routing,
/// chunking, preemption, or batching, and any divergence is a real
/// scheduling bug.
fn fake_next_token(content: &[u32]) -> u32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in content {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % 997) as u32
}

/// One replica core: the real scheduler + block manager driven exactly
/// the way `Engine` drives them, with the fake model supplying tokens.
struct FakeCore {
    sched: Scheduler,
    seqs: HashMap<u64, Sequence>,
    finished: Vec<Sequence>,
    next_id: u64,
    prefill_tokens_executed: usize,
    cached_prefix_tokens: usize,
}

impl FakeCore {
    fn new(ecfg: EngineConfig, total_blocks: usize) -> FakeCore {
        let bm = BlockManager::new(ecfg.block_size, total_blocks);
        FakeCore {
            sched: Scheduler::new(ecfg, bm),
            seqs: HashMap::new(),
            finished: vec![],
            next_id: 0,
            prefill_tokens_executed: 0,
            cached_prefix_tokens: 0,
        }
    }

    fn finish_if_done(&mut self, id: u64) {
        if let Some(r) = self.seqs[&id].should_finish() {
            let mut q = self.seqs.remove(&id).unwrap();
            q.finish(r);
            self.sched.on_finished(id);
            self.finished.push(q);
        }
    }
}

impl ReplicaCore for FakeCore {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(id, Sequence::new(id, prompt, params));
        self.sched.add(id);
        id
    }

    fn step(&mut self) -> Result<()> {
        let plan = self.sched.plan(&self.seqs);
        for v in self.sched.preempted.clone() {
            let q = self.seqs.get_mut(&v).unwrap();
            if matches!(q.state,
                        SeqState::Running | SeqState::Prefilling) {
                q.preempt();
            }
        }
        for v in self.sched.dropped.clone() {
            if let Some(mut q) = self.seqs.remove(&v) {
                q.finish(FinishReason::PoolExhausted);
                self.sched.on_finished(v);
                self.finished.push(q);
            }
        }
        for c in &plan.chunks {
            let toks = self.seqs[&c.id].full_tokens();
            {
                let q = self.seqs.get_mut(&c.id).unwrap();
                q.prefill_progress = c.end;
                if c.admitted {
                    q.cached_prefix_len = c.start;
                    self.cached_prefix_tokens += c.start;
                }
            }
            self.prefill_tokens_executed += c.end - c.start;
            self.sched.bm.register_prefix(c.id, &toks[..c.end]);
            let q = self.seqs.get_mut(&c.id).unwrap();
            if c.end == toks.len() {
                q.state = SeqState::Running;
                q.record_token(fake_next_token(&toks));
                self.finish_if_done(c.id);
            } else {
                q.state = SeqState::Prefilling;
            }
        }
        for id in plan.decode.clone() {
            let q = self.seqs.get_mut(&id).unwrap();
            q.record_token(fake_next_token(&q.full_tokens()));
            self.finish_if_done(id);
        }
        Ok(())
    }

    fn has_work(&self) -> bool {
        self.sched.has_work()
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }
    fn block_size(&self) -> usize {
        self.sched.bm.block_size
    }
    fn load(&self) -> usize {
        self.sched.waiting_len() + self.sched.running_len()
    }
    fn enable_cache_events(&mut self) {
        self.sched.bm.enable_cache_events = true;
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        self.sched.bm.take_cache_events()
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        self.sched.bm.set_cache_watermarks(wm.high, wm.low);
    }
    fn core_stats(&self) -> CoreStats {
        CoreStats {
            waiting: self.sched.waiting_len(),
            running: self.sched.running_len(),
            kv_occupancy: self.sched.bm.occupancy(),
            cache: self.sched.bm.stats.clone(),
            prefill_tokens_executed: self.prefill_tokens_executed,
            cached_prefix_tokens: self.cached_prefix_tokens,
            ttft_steps_p50: 0.0,
        }
    }
}

fn ecfg(block_size: usize) -> EngineConfig {
    EngineConfig {
        max_running: 4,
        max_batch_tokens: 64,
        decode_batches: vec![1, 2, 4, 8],
        prefill_buckets: vec![(4, 64)],
        block_size,
        ..Default::default()
    }
}

fn shared_prefixes(bs: usize) -> Vec<Vec<u32>> {
    (0..3u32)
        .map(|i| (0..(bs * (1 + i as usize)) as u32)
            .map(|t| i * 131 + t)
            .collect())
        .collect()
}

fn prompt(rng: &mut Rng, prefixes: &[Vec<u32>], uniq: u32) -> Vec<u32> {
    let mut p = prefixes[rng.below(prefixes.len())].clone();
    let extra = 1 + rng.below(12);
    p.extend((0..extra as u32).map(|t| 1000 + uniq * 31 + t));
    p
}

/// Deterministic submission schedule: request `i` is submitted before
/// step `3 * i`, with a per-request token budget. The same schedule is
/// replayable against a bare core or any router.
fn schedule(prompts: &[Vec<u32>]) -> Vec<(usize, Vec<u32>, usize)> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| (3 * i, p.clone(), 2 + i % 5))
        .collect()
}

/// Drive a bare core through the schedule; streams by submission id.
fn run_bare(mut core: FakeCore, sched: &[(usize, Vec<u32>, usize)])
    -> Vec<(u64, Vec<u32>, Option<FinishReason>)> {
    let mut out = vec![];
    let mut next = 0usize;
    for step in 0..10_000 {
        while next < sched.len() && sched[next].0 <= step {
            let (_, p, max_new) = &sched[next];
            core.submit(p.clone(), SamplingParams {
                max_new_tokens: *max_new,
                ..Default::default()
            });
            next += 1;
        }
        core.step().unwrap();
        for q in core.take_finished() {
            out.push((q.id, q.output.clone(), q.finish));
        }
        if next == sched.len() && !core.has_work() {
            break;
        }
    }
    assert!(!core.has_work(), "bare core did not drain");
    out.sort_by_key(|(id, _, _)| *id);
    out
}

/// Drive a router through the same schedule; streams by global id.
fn run_router(mut router: Router<FakeCore>,
              sched: &[(usize, Vec<u32>, usize)])
    -> (Vec<(u64, Vec<u32>, Option<FinishReason>)>, Vec<RoutedFinish>) {
    let mut fins: Vec<RoutedFinish> = vec![];
    let mut next = 0usize;
    for step in 0..10_000 {
        while next < sched.len() && sched[next].0 <= step {
            let (_, p, max_new) = &sched[next];
            router.submit(p.clone(), SamplingParams {
                max_new_tokens: *max_new,
                ..Default::default()
            });
            next += 1;
        }
        router.step().unwrap();
        fins.extend(router.take_finished());
        if next == sched.len() && !router.has_work() {
            break;
        }
    }
    assert!(!router.has_work(), "router did not drain");
    let mut out: Vec<(u64, Vec<u32>, Option<FinishReason>)> = fins
        .iter()
        .map(|f| (f.id, f.seq.output.clone(), f.seq.finish))
        .collect();
    out.sort_by_key(|(id, _, _)| *id);
    (out, fins)
}

#[test]
fn router_n1_bit_identical_to_bare_core() {
    // The golden identity: a router over one replica is a pass-through.
    // Same schedule → same global ids, same streams, same finish
    // reasons, every request served by replica 0.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0x1234);
    let prompts: Vec<Vec<u32>> =
        (0..16u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    let router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256)],
        RouterConfig::default(),
    );
    let (routed, fins) = run_router(router, &sched);
    assert_eq!(bare, routed, "N=1 router diverged from bare core");
    assert!(fins.iter().all(|f| f.replica == 0));
    // local ids equal global ids through a single replica
    assert!(fins.iter().all(|f| f.id == f.seq.id));
}

#[test]
fn router_n2_streams_match_single_core() {
    // Acceptance golden: the same trace through one core and through an
    // N=2 router (all three policies) produces the same token stream
    // per request — routing changes *where* work runs, never *what* is
    // generated.
    let bs = 4;
    let prefixes = shared_prefixes(bs);
    let mut rng = Rng::new(0xbeef);
    let prompts: Vec<Vec<u32>> =
        (0..18u32).map(|i| prompt(&mut rng, &prefixes, i)).collect();
    let sched = schedule(&prompts);
    let bare = run_bare(FakeCore::new(ecfg(bs), 256), &sched);
    for routing in [RoutingPolicy::CacheAware, RoutingPolicy::LeastLoaded,
                    RoutingPolicy::RoundRobin] {
        let router = Router::new(
            vec![FakeCore::new(ecfg(bs), 256),
                 FakeCore::new(ecfg(bs), 256)],
            RouterConfig { routing, ..Default::default() },
        );
        let (routed, fins) = run_router(router, &sched);
        assert_eq!(bare, routed,
                   "N=2 {} diverged from single core",
                   routing.as_str());
        // with round-robin both replicas must actually serve traffic
        if routing == RoutingPolicy::RoundRobin {
            assert!(fins.iter().any(|f| f.replica == 0));
            assert!(fins.iter().any(|f| f.replica == 1));
        }
    }
}

/// Shared-prefix burst trace: a donor request warms one replica's
/// cache, then `burst` requests share its prefix. Returns (total
/// prefill tokens executed, per-replica routed counts, streams).
fn run_burst(routing: RoutingPolicy)
    -> (usize, Vec<usize>, Vec<(u64, Vec<u32>)>) {
    let bs = 4;
    let prefix: Vec<u32> = (0..32).map(|t| 7000 + t).collect();
    let router_cfg = RouterConfig {
        routing,
        // 1 token per queued request: affinity dominates until a
        // replica's backlog outweighs the whole prefix
        load_penalty_tokens: 1,
        ..Default::default()
    };
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256), FakeCore::new(ecfg(bs), 256)],
        router_cfg,
    );
    // donor: prefix + 2 unique tokens; run to completion so its blocks
    // are registered and the directory is warm
    let mut donor = prefix.clone();
    donor.extend([9001, 9002]);
    router.submit(donor, SamplingParams {
        max_new_tokens: 2,
        ..Default::default()
    });
    router.run_to_completion(1000).unwrap();
    let mut fins = router.take_finished();
    // burst: 6 warm prompts, submitted together before any step
    for i in 0..6u32 {
        let mut p = prefix.clone();
        p.extend((0..3u32).map(|t| 8000 + i * 31 + t));
        router.submit(p, SamplingParams {
            max_new_tokens: 3,
            ..Default::default()
        });
    }
    router.run_to_completion(1000).unwrap();
    fins.extend(router.take_finished());
    let executed: usize = router
        .replicas()
        .iter()
        .map(|r| r.core().core_stats().prefill_tokens_executed)
        .sum();
    let routed: Vec<usize> = router
        .replicas()
        .iter()
        .map(|r| r.requests_routed)
        .collect();
    let mut streams: Vec<(u64, Vec<u32>)> = fins
        .into_iter()
        .map(|f| (f.id, f.seq.output))
        .collect();
    streams.sort_by_key(|(id, _)| *id);
    (executed, routed, streams)
}

#[test]
fn cache_aware_burst_lands_on_warm_replica() {
    let (ca_exec, ca_routed, ca_streams) =
        run_burst(RoutingPolicy::CacheAware);
    let (rr_exec, rr_routed, rr_streams) =
        run_burst(RoutingPolicy::RoundRobin);
    // identical generations either way (content-determined model)
    assert_eq!(ca_streams, rr_streams);
    // cache-aware: donor and the whole burst on replica 0
    assert_eq!(ca_routed, vec![7, 0],
               "burst did not follow the warm prefix");
    // round-robin sprays the burst across both replicas
    assert_eq!(rr_routed, vec![4, 3]);
    // the headline: strictly fewer cold prefill tokens executed
    assert!(ca_exec < rr_exec,
            "cache-aware executed {ca_exec} !< round-robin {rr_exec}");
}

#[test]
fn least_loaded_balances_a_cold_burst() {
    // with no cache hints and equal loads, least-loaded alternates via
    // the queue-depth signal instead of starving one replica
    let bs = 4;
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 256), FakeCore::new(ecfg(bs), 256)],
        RouterConfig {
            routing: RoutingPolicy::LeastLoaded,
            ..Default::default()
        },
    );
    for i in 0..8u32 {
        let p: Vec<u32> =
            (0..10u32).map(|t| 100 + i * 97 + t).collect();
        router.submit(p, SamplingParams {
            max_new_tokens: 2,
            ..Default::default()
        });
    }
    let routed: Vec<usize> = router
        .replicas()
        .iter()
        .map(|r| r.requests_routed)
        .collect();
    assert_eq!(routed, vec![4, 4], "cold burst not balanced");
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 8);
}

#[test]
fn directory_mirrors_replica_caches_randomized() {
    // After every router step the shared directory must answer prefix
    // probes exactly as each replica's own block manager would — the
    // O(1)-routing contract: hints are drained-in-order events, so
    // post-step they are in sync (mid-step staleness is unobservable
    // from the routing path).
    prop::check("directory sync", 6, |rng| {
        let bs = 2 + rng.below(4);
        let prefixes = shared_prefixes(bs);
        let n = 2 + rng.below(2);
        let cores: Vec<FakeCore> = (0..n)
            .map(|_| FakeCore::new(ecfg(bs), 24 + rng.below(48)))
            .collect();
        let mut router = Router::new(cores, RouterConfig {
            routing: RoutingPolicy::CacheAware,
            // small sliding window so evictions happen and must be
            // reflected in the directory too
            watermarks: CacheWatermarks::new(4, 2),
            ..Default::default()
        });
        let mut submitted = 0usize;
        for _ in 0..300 {
            if submitted < 24 && rng.below(2) == 0 {
                let p = prompt(rng, &prefixes, submitted as u32);
                router.submit(p, SamplingParams {
                    max_new_tokens: 1 + rng.below(6),
                    ..Default::default()
                });
                submitted += 1;
            }
            router.step().unwrap();
            router.take_finished();
            // probe with every shared prefix extended past its end (a
            // lookup never covers the whole query) and a random one
            for pre in &prefixes {
                let mut probe = pre.clone();
                probe.extend([999_999, 999_998]);
                let dir_hits = router.directory().prefix_hits(
                    &probe, bs, router.replicas().len(),
                );
                for (i, r) in router.replicas().iter().enumerate() {
                    let bm_hit = r
                        .core()
                        .sched
                        .bm
                        .cached_prefix_tokens(&probe);
                    assert_eq!(
                        dir_hits[i], bm_hit,
                        "directory diverged from replica {i}"
                    );
                }
            }
            if submitted == 24 && !router.has_work() {
                break;
            }
        }
        assert!(!router.has_work(), "workload did not drain");
    });
}

#[test]
fn sliding_window_bounds_every_replica_for_whole_run() {
    // Acceptance: with watermarks configured through the router, no
    // replica's cached-but-unreferenced population ever exceeds the
    // high watermark, conservation holds throughout, and the pool
    // drains to fully free at the end.
    prop::check("router sliding window", 6, |rng| {
        let bs = 2 + rng.below(3);
        let prefixes = shared_prefixes(bs);
        let high = 2 + rng.below(4);
        let low = rng.below(high + 1);
        let mut router = Router::new(
            vec![
                FakeCore::new(ecfg(bs), 32 + rng.below(32)),
                FakeCore::new(ecfg(bs), 32 + rng.below(32)),
            ],
            RouterConfig {
                routing: RoutingPolicy::CacheAware,
                watermarks: CacheWatermarks::new(high, low),
                ..Default::default()
            },
        );
        let mut submitted = 0usize;
        let mut finished = 0usize;
        for _ in 0..600 {
            if submitted < 30 && rng.below(2) == 0 {
                let p = prompt(rng, &prefixes, submitted as u32);
                router.submit(p, SamplingParams {
                    max_new_tokens: 1 + rng.below(5),
                    ..Default::default()
                });
                submitted += 1;
            }
            router.step().unwrap();
            finished += router.take_finished().len();
            for r in router.replicas() {
                let bm = &r.core().sched.bm;
                assert!(bm.cached_unreferenced() <= high,
                        "window exceeded: {} > {high}",
                        bm.cached_unreferenced());
                assert!(bm.check_conservation(), "conservation broken");
            }
            if submitted == 30 && !router.has_work() {
                break;
            }
        }
        assert!(!router.has_work(), "workload did not drain");
        assert_eq!(finished, submitted);
        for r in router.replicas() {
            let bm = &r.core().sched.bm;
            assert_eq!(bm.free_blocks(), bm.total_blocks,
                       "pool did not drain to free");
        }
    });
}

#[test]
fn stats_rows_roundtrip_through_wire_json() {
    // End-to-end stats check against live rows: submit traffic, step,
    // snapshot, serialize with the server's encoder, parse back.
    let bs = 4;
    let mut router = Router::new(
        vec![FakeCore::new(ecfg(bs), 64), FakeCore::new(ecfg(bs), 64)],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
    );
    for i in 0..4u32 {
        let p: Vec<u32> = (0..12u32).map(|t| i * 131 + t + 1).collect();
        router.submit(p, SamplingParams {
            max_new_tokens: 4,
            ..Default::default()
        });
    }
    for _ in 0..3 {
        router.step().unwrap();
    }
    let rows = router.stats();
    let v = json::parse(&sqplus::server::stats_json(&rows).to_string())
        .unwrap();
    let reps = v.get("replicas").as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    for (i, rep) in reps.iter().enumerate() {
        assert_eq!(rep.get("id").as_usize(), Some(i));
        assert_eq!(rep.get("requests_routed").as_usize(),
                   Some(rows[i].requests_routed));
        assert_eq!(rep.get("waiting").as_usize(),
                   Some(rows[i].core.waiting));
        assert_eq!(rep.get("running").as_usize(),
                   Some(rows[i].core.running));
        assert_eq!(rep.get("prefill_tokens_executed").as_usize(),
                   Some(rows[i].core.prefill_tokens_executed));
    }
    assert_eq!(rows[0].requests_routed + rows[1].requests_routed, 4);
    router.run_to_completion(1000).unwrap();
    assert_eq!(router.take_finished().len(), 4);
}
