//! Concurrent server lifecycle tests over the threaded serving loop —
//! N clients pipelining generate / stats / metrics / streaming
//! requests against stub replica cores (no PJRT runtime), exercising
//! the full TCP seam: accept loop → per-connection threads → inbox →
//! per-replica workers → bounded streaming delivery → client sockets.
//!
//! Locked down:
//! * concurrent clients each get coherent responses (their own ids,
//!   their own token streams, correct budgets) while stats/metrics
//!   admin requests interleave on other connections;
//! * a replica killed mid-stream on its own worker thread is invisible
//!   to clients: every stream still arrives whole (contiguous indices,
//!   streamed tokens == final response tokens) and the death is
//!   observable only in the stats snapshot;
//! * shutdown with streams in flight delivers a finish line to every
//!   client — no stream is silently dropped.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;

use sqplus::config::{EngineConfig, RouterConfig, RoutingPolicy};
use sqplus::coordinator::fake::{EchoCore, FakeCore};
use sqplus::coordinator::fault::{FaultSpec, FaultyCore};
use sqplus::server::{Client, ServeOptions, Server};
use sqplus::util::json;

fn ecfg(block_size: usize) -> EngineConfig {
    EngineConfig {
        max_running: 4,
        max_batch_tokens: 64,
        decode_batches: vec![1, 2, 4, 8],
        prefill_buckets: vec![(4, 64)],
        block_size,
        ..Default::default()
    }
}

fn fake_server(n: usize) -> Server {
    let cores: Vec<FakeCore> =
        (0..n).map(|_| FakeCore::new(ecfg(4), 128)).collect();
    Server::spawn_core(
        cores,
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        0,
        ServeOptions::default(),
    )
    .unwrap()
}

/// A unique prompt per (client, round) so every stream is
/// content-distinct under the content-determined fake model.
fn prompt_for(client: usize, round: usize) -> Vec<u32> {
    (0..8u32)
        .map(|t| 1000 + (client as u32) * 991 + (round as u32) * 53 + t)
        .collect()
}

#[test]
fn clients_pipeline_generate_stats_metrics_concurrently() {
    let server = fake_server(2);
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|ci| {
            thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for round in 0..3 {
                    let p = prompt_for(ci, round);
                    let resp = c.request(&p, 3).unwrap();
                    assert_eq!(resp.get("finish").as_str(),
                               Some("max_tokens"));
                    assert_eq!(
                        resp.get("tokens").as_arr().unwrap().len(),
                        3
                    );
                    let stats = c.stats().unwrap();
                    assert_eq!(
                        stats.get("replicas").as_arr().unwrap().len(),
                        2
                    );
                    let metrics = c.metrics().unwrap();
                    assert!(metrics.contains("sqplus_replica_up"),
                            "metrics text missing the up gauge");
                    let ps = prompt_for(ci, round + 100);
                    let (tokens, fin) =
                        c.request_stream(&ps, 4).unwrap();
                    assert_eq!(fin.get("finish").as_str(),
                               Some("max_tokens"));
                    let streamed: Vec<f64> = tokens
                        .iter()
                        .map(|t| t.get("token").as_f64().unwrap())
                        .collect();
                    let final_tokens: Vec<f64> = fin
                        .get("tokens")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|t| t.as_f64().unwrap())
                        .collect();
                    assert_eq!(streamed, final_tokens,
                               "streamed tokens != final tokens");
                    for (i, t) in tokens.iter().enumerate() {
                        assert_eq!(t.get("index").as_usize(), Some(i));
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn echo_server_serves_concurrent_clients() {
    let server = Server::spawn_core(
        vec![EchoCore::new()],
        RouterConfig::default(),
        0,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|ci| {
            thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for round in 0..5 {
                    let first = 7_000 + (ci * 10 + round) as u32;
                    let p = vec![first, 1, 2];
                    let resp = c.request(&p, 4).unwrap();
                    // the echo core replies with the first prompt
                    // token — each client must get its own back
                    let toks = resp.get("tokens").as_arr().unwrap();
                    assert_eq!(toks.len(), 1);
                    assert_eq!(toks[0].as_f64(), Some(first as f64));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn replica_death_mid_stream_is_invisible_to_clients() {
    // Replica 0 dies permanently on its third step while 16-token
    // streams are in flight on both workers. Clients must never
    // notice: every stream arrives whole and duplicate-free; only the
    // stats snapshot records the death and the replays.
    let server = Server::spawn_core(
        vec![
            FaultyCore::new(FakeCore::new(ecfg(4), 128),
                            FaultSpec::FailOnStepK { k: 3 }),
            FaultyCore::new(FakeCore::new(ecfg(4), 128),
                            FaultSpec::FailOnStepK { k: usize::MAX }),
        ],
        RouterConfig {
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        },
        0,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|ci| {
            thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let p = prompt_for(ci, 7);
                let (tokens, fin) = c.request_stream(&p, 16).unwrap();
                assert_eq!(fin.get("finish").as_str(),
                           Some("max_tokens"),
                           "stream died with the replica: {fin}");
                assert_eq!(tokens.len(), 16);
                for (i, t) in tokens.iter().enumerate() {
                    assert_eq!(t.get("index").as_usize(), Some(i),
                               "non-contiguous stream after replay");
                }
                let streamed: Vec<f64> = tokens
                    .iter()
                    .map(|t| t.get("token").as_f64().unwrap())
                    .collect();
                let final_tokens: Vec<f64> = fin
                    .get("tokens")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|t| t.as_f64().unwrap())
                    .collect();
                assert_eq!(streamed, final_tokens,
                           "replay duplicated or dropped a token");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // the death is visible in stats: one dead replica, work replayed
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("router").get("alive").as_usize(), Some(1));
    assert_eq!(stats.get("router").get("dead").as_usize(), Some(1));
    assert!(stats.get("router").get("replayed").as_usize().unwrap()
                >= 1,
            "no replay recorded for a mid-stream death");
    server.shutdown();
}

#[test]
fn shutdown_with_inflight_streams_delivers_finish_lines() {
    // Three clients open 48-token streams and confirm the stream is
    // live (first token line read) before the server is told to shut
    // down. Shutdown drains the workers, so every client must still
    // receive its full stream and a finish line — never a silent EOF.
    let server = fake_server(2);
    let addr = server.addr();
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let handles: Vec<_> = (0..3)
        .map(|ci| {
            let started = started_tx.clone();
            thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream);
                let p = prompt_for(ci, 9);
                let body: Vec<String> =
                    p.iter().map(|t| t.to_string()).collect();
                writeln!(
                    reader.get_mut(),
                    "{{\"prompt\":[{}],\"max_new_tokens\":48,\
                     \"stream\":true}}",
                    body.join(",")
                )
                .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let first = json::parse(line.trim()).unwrap();
                assert!(first.get("token").as_f64().is_some(),
                        "first line is not a token: {line}");
                // the stream is live; let the main thread pull the
                // plug while the rest is still being generated
                started.send(()).unwrap();
                let mut count = 1usize;
                loop {
                    let mut line = String::new();
                    assert!(reader.read_line(&mut line).unwrap() > 0,
                            "connection closed without a finish line");
                    let v = json::parse(line.trim()).unwrap();
                    if v.get("token").as_f64().is_some() {
                        assert_eq!(v.get("index").as_usize(),
                                   Some(count));
                        count += 1;
                    } else {
                        assert_eq!(v.get("finish").as_str(),
                                   Some("max_tokens"),
                                   "stream ended abnormally: {v}");
                        assert_eq!(count, 48,
                                   "stream truncated at shutdown");
                        return;
                    }
                }
            })
        })
        .collect();
    for _ in 0..3 {
        started_rx.recv().unwrap();
    }
    server.shutdown();
    for h in handles {
        h.join().unwrap();
    }
}
