//! `sqlint` fixture harness: proves every pass fires on the bad
//! fixtures, stays quiet on the allowed ones, and that the CLI's exit
//! codes and baseline workflow behave. The fixture trees under
//! `tests/lint_fixtures/` are scanned, never compiled (the walker
//! skips that directory on normal runs).

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::Command;

use sqplus::lint;

fn fixture(dir: &str) -> Vec<PathBuf> {
    vec![PathBuf::from(format!("tests/lint_fixtures/{dir}"))]
}

fn by_pass(diags: &[lint::Diagnostic]) -> HashMap<&str, usize> {
    let mut out: HashMap<&str, usize> = HashMap::new();
    for d in diags {
        *out.entry(d.pass.as_str()).or_insert(0) += 1;
    }
    out
}

#[test]
fn bad_fixtures_trip_every_pass() {
    let diags = lint::run_paths(&fixture("bad")).expect("fixtures readable");
    let counts = by_pass(&diags);
    assert_eq!(counts.get("panic"), Some(&6), "{diags:#?}");
    assert_eq!(counts.get("determinism"), Some(&5), "{diags:#?}");
    assert_eq!(counts.get("locks"), Some(&3), "{diags:#?}");
    assert_eq!(counts.get("wire"), Some(&2), "{diags:#?}");
    assert_eq!(counts.get("events"), Some(&3), "{diags:#?}");
    assert_eq!(counts.get("marker"), Some(&1), "{diags:#?}");
    assert_eq!(diags.len(), 20, "{diags:#?}");
    // output is sorted by (path, line, pass) so diffs are stable
    let mut sorted = diags.clone();
    sorted.sort_by(|a, b| {
        (&a.path, a.line, &a.pass).cmp(&(&b.path, b.line, &b.pass))
    });
    assert_eq!(diags, sorted);
}

#[test]
fn bad_fixture_lines_are_precise() {
    let diags = lint::run_paths(&fixture("bad")).expect("fixtures readable");
    let has = |pass: &str, file: &str, line: usize| {
        diags.iter().any(|d| {
            d.pass == pass && d.path.ends_with(file) && d.line == line
        })
    };
    // one representative site per rule variant
    assert!(has("panic", "panic_bad.rs", 7), "unwrap");
    assert!(has("panic", "panic_bad.rs", 10), "panic! macro");
    assert!(has("panic", "panic_bad.rs", 19), "map index [&..]");
    assert!(has("marker", "panic_bad.rs", 18), "bare marker");
    assert!(has("determinism", "determinism_bad.rs", 12), "Instant::now");
    assert!(has("determinism", "determinism_bad.rs", 16), "for over map");
    assert!(has("locks", "locks_bad.rs", 7), "lock().unwrap()");
    assert!(has("locks", "worker.rs", 14), "send under guard");
    assert!(has("wire", "wire_bad.rs", 7), "field off the wire");
    assert!(has("events", "events_bad.rs", 13), "wildcard event arm");
    assert!(has("events", "events_bad.rs", 20), "guarded catch-all");
    assert!(has("events", "events_bad.rs", 21), "binding catch-all");
}

#[test]
fn allowed_fixtures_are_clean() {
    let diags =
        lint::run_paths(&fixture("allowed")).expect("fixtures readable");
    assert!(diags.is_empty(), "{diags:#?}");
}

#[test]
fn shipped_tree_lints_clean() {
    // the same invariant `make lint` / CI enforces, minus the baseline
    // (which ships empty)
    let diags = lint::run_paths(&[PathBuf::from("src")])
        .expect("src readable");
    assert!(diags.is_empty(), "{diags:#?}");
}

fn sqlint_cmd() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sqlint"))
}

#[test]
fn cli_exit_codes() {
    let bad = sqlint_cmd()
        .arg("tests/lint_fixtures/bad")
        .output()
        .expect("spawn sqlint");
    assert_eq!(bad.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("[panic]"), "{stdout}");
    assert!(stdout.contains("[wire]"), "{stdout}");

    let ok = sqlint_cmd()
        .arg("tests/lint_fixtures/allowed")
        .output()
        .expect("spawn sqlint");
    assert_eq!(ok.status.code(), Some(0));

    let usage = sqlint_cmd().arg("--nope").output().expect("spawn sqlint");
    assert_eq!(usage.status.code(), Some(2));

    let missing = sqlint_cmd()
        .args(["--baseline", "does-not-exist.txt", "src"])
        .output()
        .expect("spawn sqlint");
    assert_eq!(missing.status.code(), Some(2));
}

#[test]
fn baseline_suppresses_known_findings() {
    let base = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join("sqlint-fixture-baseline.txt");
    let wrote = sqlint_cmd()
        .args(["--write-baseline"])
        .arg(&base)
        .arg("tests/lint_fixtures/bad")
        .output()
        .expect("spawn sqlint");
    assert_eq!(wrote.status.code(), Some(0));
    let filtered = sqlint_cmd()
        .args(["--baseline"])
        .arg(&base)
        .arg("tests/lint_fixtures/bad")
        .output()
        .expect("spawn sqlint");
    assert_eq!(filtered.status.code(), Some(0), "baselined run is clean");
    // the baseline is keyed, not a blanket waiver: the allowed tree's
    // keys are absent so a *new* finding would still fail
    let keys = std::fs::read_to_string(&base).expect("baseline written");
    assert_eq!(
        keys.lines().filter(|l| !l.starts_with('#')).count(),
        20,
        "{keys}"
    );
}
