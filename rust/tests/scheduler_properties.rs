//! Randomized-workload property tests over scheduler + block manager —
//! pure accounting, no PJRT runtime needed, so these run everywhere
//! (including CI without artifacts).
//!
//! Invariants locked down, with and without prefix caching:
//! * block conservation (`check_conservation`) after every plan;
//! * no double-free when a sequence is preempted while its prefix
//!   blocks are shared with other live sequences;
//! * refcounts return to zero (whole pool free) after all sequences
//!   finish;
//! * FCFS admission order, LIFO preemption order.

use std::collections::HashMap;

use sqplus::config::EngineConfig;
use sqplus::coordinator::block_manager::{Alloc, BlockManager};
use sqplus::coordinator::scheduler::{Scheduler, StepPlan};
use sqplus::coordinator::sequence::{
    SamplingParams, SeqState, Sequence,
};
use sqplus::util::prop;
use sqplus::util::rng::Rng;

/// Deterministic token content for a sequence: one of a few shared
/// prefixes (to provoke cache hits) plus a unique suffix.
fn prompt(rng: &mut Rng, prefixes: &[Vec<u32>], uniq: u32) -> Vec<u32> {
    let mut p = prefixes[rng.below(prefixes.len())].clone();
    let extra = 1 + rng.below(12);
    p.extend((0..extra as u32).map(|t| 1000 + uniq * 31 + t));
    p
}

/// Drive a scheduler the way the engine does: prefill plans register
/// their blocks, decode plans record a token, sequences finish at their
/// token budget, preempted sequences are reset for recompute. Returns
/// the admission order observed.
fn drive(
    s: &mut Scheduler, seqs: &mut HashMap<u64, Sequence>, rng: &mut Rng,
    steps: usize, submit_total: usize, prefixes: &[Vec<u32>],
) -> Vec<u64> {
    let mut next_id = 0u64;
    let mut admission_order = vec![];
    // model of the running set in admission order, for LIFO checking
    let mut running_model: Vec<u64> = vec![];
    for _ in 0..steps {
        if next_id < submit_total as u64 && rng.below(2) == 0 {
            let p = prompt(rng, prefixes, next_id as u32);
            seqs.insert(
                next_id,
                Sequence::new(next_id, p, SamplingParams::default()),
            );
            s.add(next_id);
            next_id += 1;
        }
        let plan = s.plan(seqs);
        // LIFO preemption: victims must come off the back of the
        // running set, most recently admitted first
        for &victim in &s.preempted {
            assert_eq!(
                running_model.pop(),
                Some(victim),
                "preemption not LIFO"
            );
            let q = seqs.get_mut(&victim).unwrap();
            if q.state == SeqState::Running {
                q.preempt();
            }
        }
        match plan {
            StepPlan::Prefill { ids, cached } => {
                assert_eq!(ids.len(), cached.len());
                for (i, id) in ids.iter().enumerate() {
                    let toks = seqs[id].full_tokens();
                    // the hit the scheduler reported is what the block
                    // manager sees, block-aligned and never the whole
                    // content
                    assert_eq!(cached[i] % s.bm.block_size, 0);
                    assert!(cached[i] < toks.len());
                    // engine side: mark running, register blocks
                    seqs.get_mut(id).unwrap().state = SeqState::Running;
                    s.bm.register_prefix(*id, &toks);
                    admission_order.push(*id);
                    running_model.push(*id);
                }
            }
            StepPlan::Decode { ids } => {
                for id in ids {
                    assert!(s.bm.holds(id) > 0, "decoding unallocated");
                    let q = seqs.get_mut(&id).unwrap();
                    q.record_token(7);
                    if q.output.len() >= 4 + (id % 5) as usize {
                        q.finish(
                            sqplus::coordinator::sequence::FinishReason
                                ::MaxTokens,
                        );
                        s.on_finished(id);
                        running_model.retain(|&r| r != id);
                    }
                }
            }
            StepPlan::Idle => {
                // Idle with fresh preemptions means the scheduler hit
                // the cannot-make-progress case and dropped the last
                // victim (a single sequence exceeding the pool); the
                // engine finishes it with an error.
                if s.running_len() == 0 {
                    if let Some(&dropped) = s.preempted.last() {
                        seqs.get_mut(&dropped).unwrap().state =
                            SeqState::Finished;
                        s.on_finished(dropped);
                    }
                }
                if next_id == submit_total as u64 && !s.has_work() {
                    break;
                }
            }
        }
        assert!(s.bm.check_conservation(), "conservation violated");
        assert!(s.running_len() <= s.cfg.max_running);
        assert!(s.bm.free_blocks() <= s.bm.total_blocks);
    }
    admission_order
}

fn shared_prefixes(bs: usize) -> Vec<Vec<u32>> {
    (0..3u32)
        .map(|i| (0..(bs * (1 + i as usize)) as u32)
            .map(|t| i * 131 + t)
            .collect())
        .collect()
}

#[test]
fn conservation_and_lifo_under_random_workload() {
    for enable in [false, true] {
        prop::check("scheduler conservation+LIFO", 12, |rng| {
            let bs = 2 + rng.below(6);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 1 + rng.below(6),
                    max_batch_tokens: 32 + rng.below(96),
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 64)],
                    enable_prefix_caching: enable,
                    ..Default::default()
                },
                BlockManager::new(bs, 24 + rng.below(48)),
            );
            let mut seqs = HashMap::new();
            drive(&mut s, &mut seqs, rng, 300, 40, &shared_prefixes(bs));
        });
    }
}

#[test]
fn refcounts_zero_after_everything_finishes() {
    for enable in [false, true] {
        prop::check("drain to empty pool", 12, |rng| {
            let bs = 2 + rng.below(4);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 2 + rng.below(4),
                    max_batch_tokens: 128,
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 64)],
                    enable_prefix_caching: enable,
                    ..Default::default()
                },
                // ample pool: every sequence can finish
                BlockManager::new(bs, 128),
            );
            let mut seqs = HashMap::new();
            drive(&mut s, &mut seqs, rng, 2000, 24, &shared_prefixes(bs));
            assert!(!s.has_work(), "workload did not drain");
            assert!(s.bm.check_conservation());
            // cached blocks may remain (evictable), but nothing is
            // referenced: the whole pool counts as free again
            assert_eq!(s.bm.free_blocks(), s.bm.total_blocks);
            for id in seqs.keys() {
                assert_eq!(s.bm.holds(*id), 0);
            }
        });
    }
}

#[test]
fn fcfs_admission_order_without_pressure() {
    for enable in [false, true] {
        prop::check("FCFS admission", 8, |rng| {
            let bs = 2 + rng.below(4);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 4,
                    max_batch_tokens: 256,
                    decode_batches: vec![1, 2, 4],
                    prefill_buckets: vec![(4, 64)],
                    enable_prefix_caching: enable,
                    ..Default::default()
                },
                BlockManager::new(bs, 512), // no preemption pressure
            );
            let mut seqs = HashMap::new();
            let order = drive(&mut s, &mut seqs, rng, 2000, 20,
                              &shared_prefixes(bs));
            assert!(!s.has_work());
            // without preemption, admission must be submission order
            let sorted: Vec<u64> = (0..order.len() as u64).collect();
            assert_eq!(order, sorted, "FCFS violated");
        });
    }
}

#[test]
fn no_double_free_on_preempt_while_shared() {
    // A registers its prefix; B and C share it. Preempting B (release)
    // then finishing C and A must free every block exactly once.
    let bs = 4;
    let prefix: Vec<u32> = (0..8).collect();
    let mk = |id: u64, uniq: u32| {
        let mut p = prefix.clone();
        p.extend([100 + uniq, 101 + uniq]);
        Sequence::new(id, p, SamplingParams::default())
    };
    let mut bm = BlockManager::new(bs, 16);
    bm.watermark_blocks = 0;
    let a = mk(0, 0).full_tokens();
    let b = mk(1, 10).full_tokens();
    let c = mk(2, 20).full_tokens();
    assert_eq!(bm.allocate(0, &a), Alloc::Ok);
    bm.register_prefix(0, &a);
    assert_eq!(bm.allocate(1, &b), Alloc::Ok);
    assert_eq!(bm.allocate(2, &c), Alloc::Ok);
    // both B and C share A's two prefix blocks
    assert_eq!(bm.stats.shared_blocks, 4);
    assert_eq!(bm.table(0).unwrap()[..2], bm.table(1).unwrap()[..2]);
    assert!(bm.check_conservation());
    // preempt B: its shared blocks drop one reference, not freed
    bm.release(1);
    assert!(bm.check_conservation());
    assert_eq!(bm.holds(0), 3);
    assert_eq!(bm.holds(2), 3);
    // releasing B again is a no-op, not a second decrement
    bm.release(1);
    assert!(bm.check_conservation());
    bm.release(0);
    bm.release(2);
    assert!(bm.check_conservation());
    assert_eq!(bm.free_blocks(), bm.total_blocks);
}

#[test]
fn preempt_while_shared_under_scheduler_pressure() {
    // End-to-end through the scheduler: tight pool, shared prefixes,
    // heavy decode growth — exercised with caching on, where preempting
    // one sharer must never free blocks the other still uses.
    prop::check("preempt-while-shared", 10, |rng| {
        let bs = 2 + rng.below(3);
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 3,
                max_batch_tokens: 96,
                decode_batches: vec![1, 2, 4],
                prefill_buckets: vec![(4, 64)],
                enable_prefix_caching: true,
                ..Default::default()
            },
            // just enough for ~2 sequences: forces preempt of sharers
            BlockManager::new(bs, 10 + rng.below(6)),
        );
        let mut seqs = HashMap::new();
        drive(&mut s, &mut seqs, rng, 600, 16, &shared_prefixes(bs));
    });
}
