//! Randomized-workload property tests over scheduler + block manager —
//! pure accounting, no PJRT runtime needed, so these run everywhere
//! (including CI without artifacts).
//!
//! Invariants locked down, with and without prefix caching and across
//! chunk sizes:
//! * block conservation (`check_conservation`) after every plan;
//! * no double-free when a sequence is preempted while its prefix
//!   blocks are shared with other live sequences — including preemption
//!   *while partially prefilled*;
//! * refcounts return to zero (whole pool free) after all sequences
//!   finish;
//! * FCFS admission order, LIFO preemption order;
//! * chunk ranges per sequence tile `[hit, target)` exactly — no gaps,
//!   no overlaps — and cold chunks never exceed the largest bucket;
//! * determinism: under a deterministic fake model, any
//!   `max_prefill_chunk` (and legacy unchunked mode) produces the same
//!   token stream per sequence;
//! * single-walk admission: the hit the allocator returns (and the
//!   scheduler budgets against) equals a reference double-walk probe on
//!   a pre-plan snapshot, and a plan performs at most one hash-chain
//!   walk per admission attempt.

use std::collections::HashMap;

use sqplus::config::{EngineConfig, KvCacheMode};
use sqplus::coordinator::block_manager::{Alloc, BlockManager};
use sqplus::coordinator::fake::FakeCore;
use sqplus::coordinator::replica::ReplicaCore;
use sqplus::coordinator::scheduler::{Scheduler, StepPlan};
use sqplus::coordinator::sequence::{
    FinishReason, SamplingParams, SeqState, Sequence,
};
use sqplus::util::prop;
use sqplus::util::rng::Rng;

/// Deterministic token content for a sequence: one of a few shared
/// prefixes (to provoke cache hits) plus a unique suffix.
fn prompt(rng: &mut Rng, prefixes: &[Vec<u32>], uniq: u32) -> Vec<u32> {
    let mut p = prefixes[rng.below(prefixes.len())].clone();
    let extra = 1 + rng.below(12);
    p.extend((0..extra as u32).map(|t| 1000 + uniq * 31 + t));
    p
}

/// Deterministic fake model: the next token is a pure function of the
/// content so far. Any correct scheduler must therefore produce the
/// same stream for a sequence regardless of how its prefill was
/// chunked, interleaved, or preempted-and-recomputed.
fn fake_next_token(content: &[u32]) -> u32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in content {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % 997) as u32
}

/// Drive a scheduler the way the engine does: chunks advance cursors
/// and register blocks, completed prefills and decodes record a token
/// from the fake model, sequences finish at their token budget,
/// preempted sequences are reset for recompute, dropped sequences
/// finish with `PoolExhausted`. Returns the admission order observed.
fn drive(
    s: &mut Scheduler, seqs: &mut HashMap<u64, Sequence>, rng: &mut Rng,
    steps: usize, submit_total: usize, prefixes: &[Vec<u32>],
) -> Vec<u64> {
    let mut next_id = 0u64;
    let mut admission_order = vec![];
    // model of the running set in admission order, for LIFO checking
    let mut running_model: Vec<u64> = vec![];
    for _ in 0..steps {
        if next_id < submit_total as u64 && rng.below(2) == 0 {
            let p = prompt(rng, prefixes, next_id as u32);
            seqs.insert(
                next_id,
                Sequence::new(next_id, p, SamplingParams::default()),
            );
            s.add(next_id);
            next_id += 1;
        }
        let plan = s.plan(seqs);
        // LIFO preemption: victims must come off the back of the
        // running set, most recently admitted first
        for &victim in &s.preempted {
            assert_eq!(
                running_model.pop(),
                Some(victim),
                "preemption not LIFO"
            );
            let q = seqs.get_mut(&victim).unwrap();
            if q.state == SeqState::Running
                || q.state == SeqState::Prefilling
            {
                q.preempt();
            }
        }
        // dropped: either the sole running sequence outgrew the pool
        // (comes off the back, like a preemption) or a waiting head
        // whose content can never fit; the engine finishes both with
        // PoolExhausted
        for &victim in &s.dropped {
            if running_model.last() == Some(&victim) {
                running_model.pop();
            } else {
                assert!(!running_model.contains(&victim),
                        "mid-list drop");
            }
            let q = seqs.get_mut(&victim).unwrap();
            q.finish(FinishReason::PoolExhausted);
        }
        for c in &plan.chunks {
            let toks = seqs[&c.id].full_tokens();
            // chunk invariants: the range tiles the prefill pass
            assert!(c.start < c.end && c.end <= toks.len());
            if c.admitted {
                // first chunk starts at the (block-aligned) cache hit
                assert_eq!(c.start % s.bm.block_size, 0);
                admission_order.push(c.id);
                running_model.push(c.id);
            } else {
                assert_eq!(c.start, seqs[&c.id].prefill_progress,
                           "chunk gap/overlap");
            }
            // the table must cover every row the chunk computes
            assert!(s.bm.holds(c.id) * s.bm.block_size >= c.end);
            // engine side: advance cursor, register, maybe complete
            let q = seqs.get_mut(&c.id).unwrap();
            q.prefill_progress = c.end;
            q.cached_prefix_len =
                if c.admitted { c.start } else { q.cached_prefix_len };
            if c.end == toks.len() {
                q.state = SeqState::Running;
                let t = fake_next_token(&toks);
                q.record_token(t);
            } else {
                q.state = SeqState::Prefilling;
            }
            s.bm.register_prefix(c.id, &toks[..c.end]);
        }
        for &id in &plan.decode {
            assert!(s.bm.holds(id) > 0, "decoding unallocated");
            assert_eq!(seqs[&id].state, SeqState::Running);
            let q = seqs.get_mut(&id).unwrap();
            let t = fake_next_token(&q.full_tokens());
            q.record_token(t);
            if q.output.len() >= 4 + (id % 5) as usize {
                q.finish(FinishReason::MaxTokens);
                s.on_finished(id);
                running_model.retain(|&r| r != id);
            }
        }
        if plan.is_idle()
            && next_id == submit_total as u64
            && !s.has_work()
        {
            break;
        }
        assert!(s.bm.check_conservation(), "conservation violated");
        assert!(s.running_len() <= s.cfg.max_running);
        assert!(s.bm.free_blocks() <= s.bm.total_blocks);
    }
    admission_order
}

fn shared_prefixes(bs: usize) -> Vec<Vec<u32>> {
    (0..3u32)
        .map(|i| (0..(bs * (1 + i as usize)) as u32)
            .map(|t| i * 131 + t)
            .collect())
        .collect()
}

#[test]
fn conservation_and_lifo_under_random_workload() {
    for enable in [false, true] {
        for chunk in [0usize, 5] {
            prop::check("scheduler conservation+LIFO", 8, |rng| {
                let bs = 2 + rng.below(6);
                let mut s = Scheduler::new(
                    EngineConfig {
                        max_running: 1 + rng.below(6),
                        max_batch_tokens: 32 + rng.below(96),
                        decode_batches: vec![1, 2, 4, 8],
                        prefill_buckets: vec![(4, 64)],
                        enable_prefix_caching: enable,
                        max_prefill_chunk: chunk,
                        ..Default::default()
                    },
                    BlockManager::new(bs, 24 + rng.below(48)),
                );
                let mut seqs = HashMap::new();
                drive(&mut s, &mut seqs, rng, 400, 40,
                      &shared_prefixes(bs));
            });
        }
    }
}

#[test]
fn legacy_mode_conservation_and_lifo() {
    for enable in [false, true] {
        prop::check("legacy scheduler conservation+LIFO", 8, |rng| {
            let bs = 2 + rng.below(6);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 1 + rng.below(6),
                    max_batch_tokens: 32 + rng.below(96),
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 64)],
                    enable_prefix_caching: enable,
                    enable_chunked_prefill: false,
                    ..Default::default()
                },
                BlockManager::new(bs, 24 + rng.below(48)),
            );
            let mut seqs = HashMap::new();
            drive(&mut s, &mut seqs, rng, 400, 40, &shared_prefixes(bs));
        });
    }
}

#[test]
fn refcounts_zero_after_everything_finishes() {
    for chunk in [0usize, 3, 16] {
        prop::check("drain to empty pool", 8, |rng| {
            let bs = 2 + rng.below(4);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 2 + rng.below(4),
                    max_batch_tokens: 128,
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 64)],
                    max_prefill_chunk: chunk,
                    ..Default::default()
                },
                // ample pool: every sequence can finish
                BlockManager::new(bs, 128),
            );
            let mut seqs = HashMap::new();
            drive(&mut s, &mut seqs, rng, 2000, 24, &shared_prefixes(bs));
            assert!(!s.has_work(), "workload did not drain");
            assert!(s.bm.check_conservation());
            // cached blocks may remain (evictable), but nothing is
            // referenced: the whole pool counts as free again
            assert_eq!(s.bm.free_blocks(), s.bm.total_blocks);
            for id in seqs.keys() {
                assert_eq!(s.bm.holds(*id), 0);
            }
        });
    }
}

#[test]
fn fcfs_admission_order_without_pressure() {
    for chunk in [0usize, 7] {
        prop::check("FCFS admission", 6, |rng| {
            let bs = 2 + rng.below(4);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 4,
                    max_batch_tokens: 256,
                    decode_batches: vec![1, 2, 4],
                    prefill_buckets: vec![(4, 64)],
                    max_prefill_chunk: chunk,
                    ..Default::default()
                },
                BlockManager::new(bs, 512), // no preemption pressure
            );
            let mut seqs = HashMap::new();
            let order = drive(&mut s, &mut seqs, rng, 2000, 20,
                              &shared_prefixes(bs));
            assert!(!s.has_work());
            // without preemption, admission must be submission order
            let sorted: Vec<u64> = (0..order.len() as u64).collect();
            assert_eq!(order, sorted, "FCFS violated");
        });
    }
}

#[test]
fn no_double_free_on_preempt_while_shared() {
    // A registers its prefix; B and C share it. Preempting B (release)
    // then finishing C and A must free every block exactly once.
    let bs = 4;
    let prefix: Vec<u32> = (0..8).collect();
    let mk = |id: u64, uniq: u32| {
        let mut p = prefix.clone();
        p.extend([100 + uniq, 101 + uniq]);
        Sequence::new(id, p, SamplingParams::default())
    };
    let mut bm = BlockManager::new(bs, 16);
    bm.watermark_blocks = 0;
    let a = mk(0, 0).full_tokens();
    let b = mk(1, 10).full_tokens();
    let c = mk(2, 20).full_tokens();
    assert!(matches!(bm.allocate(0, &a), Alloc::Ok { .. }));
    bm.register_prefix(0, &a);
    assert!(matches!(bm.allocate(1, &b), Alloc::Ok { .. }));
    assert!(matches!(bm.allocate(2, &c), Alloc::Ok { .. }));
    // both B and C share A's two prefix blocks
    assert_eq!(bm.stats.shared_blocks, 4);
    assert_eq!(bm.table(0).unwrap()[..2], bm.table(1).unwrap()[..2]);
    assert!(bm.check_conservation());
    // preempt B: its shared blocks drop one reference, not freed
    bm.release(1);
    assert!(bm.check_conservation());
    assert_eq!(bm.holds(0), 3);
    assert_eq!(bm.holds(2), 3);
    // releasing B again is a no-op, not a second decrement
    bm.release(1);
    assert!(bm.check_conservation());
    bm.release(0);
    bm.release(2);
    assert!(bm.check_conservation());
    assert_eq!(bm.free_blocks(), bm.total_blocks);
}

#[test]
fn preempt_while_shared_under_scheduler_pressure() {
    // End-to-end through the scheduler: tight pool, shared prefixes,
    // heavy decode growth — exercised with caching on, where preempting
    // one sharer must never free blocks the other still uses.
    for chunk in [0usize, 4] {
        prop::check("preempt-while-shared", 8, |rng| {
            let bs = 2 + rng.below(3);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 3,
                    max_batch_tokens: 96,
                    decode_batches: vec![1, 2, 4],
                    prefill_buckets: vec![(4, 64)],
                    enable_prefix_caching: true,
                    max_prefill_chunk: chunk,
                    ..Default::default()
                },
                // just enough for ~2 sequences: forces preempt of sharers
                BlockManager::new(bs, 10 + rng.below(6)),
            );
            let mut seqs = HashMap::new();
            drive(&mut s, &mut seqs, rng, 600, 16, &shared_prefixes(bs));
        });
    }
}

#[test]
fn preempt_while_partially_prefilled_drains_refcounts() {
    // Small chunks + a pool barely bigger than one sequence: sequences
    // are routinely preempted mid-prefill (cursor reset, blocks
    // released). After the workload drains, no block may stay
    // referenced.
    prop::check("preempt mid-prefill", 10, |rng| {
        let bs = 2 + rng.below(3);
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 3,
                max_batch_tokens: 64,
                decode_batches: vec![1, 2],
                prefill_buckets: vec![(4, 64)],
                max_prefill_chunk: 1 + rng.below(3),
                ..Default::default()
            },
            BlockManager::new(bs, 12 + rng.below(4)),
        );
        let mut seqs = HashMap::new();
        drive(&mut s, &mut seqs, rng, 1500, 12, &shared_prefixes(bs));
        assert!(!s.has_work(), "workload did not drain");
        let preempted_mid: usize = seqs
            .values()
            .map(|q| q.preemptions)
            .sum();
        assert!(preempted_mid > 0 || seqs.is_empty(),
                "workload never preempted (test too weak)");
        assert_eq!(s.bm.free_blocks(), s.bm.total_blocks);
        assert!(s.bm.check_conservation());
    });
}

#[test]
fn chunk_boundary_on_block_boundary() {
    // chunk size == block size, prompt an exact multiple of both: every
    // chunk ends exactly on a block boundary and registration after
    // each chunk caches exactly the blocks covered so far.
    let bs = 4;
    let mut s = Scheduler::new(
        EngineConfig {
            max_running: 2,
            max_batch_tokens: 64,
            decode_batches: vec![1, 2],
            prefill_buckets: vec![(4, 64)],
            max_prefill_chunk: bs,
            ..Default::default()
        },
        BlockManager::new(bs, 32),
    );
    let prompt: Vec<u32> = (0..16).collect(); // 4 blocks, 4 chunks
    let mut seqs = HashMap::new();
    seqs.insert(0, Sequence::new(0, prompt.clone(),
                                 SamplingParams::default()));
    s.add(0);
    let mut bounds = vec![];
    for _ in 0..8 {
        let plan = s.plan(&seqs);
        if plan.is_idle() {
            break;
        }
        for c in &plan.chunks {
            bounds.push((c.start, c.end));
            assert_eq!(c.end % bs, 0, "chunk must end on block boundary");
            let q = seqs.get_mut(&c.id).unwrap();
            q.prefill_progress = c.end;
            q.state = if c.end == prompt.len() {
                SeqState::Running
            } else {
                SeqState::Prefilling
            };
            s.bm.register_prefix(c.id, &prompt[..c.end]);
            // every block covered so far is now cached: a probe one
            // token longer hits all of them (lookup never covers the
            // whole query)
            let mut probe = prompt[..c.end].to_vec();
            probe.push(999);
            assert_eq!(s.bm.cached_prefix_tokens(&probe), c.end);
        }
        if seqs[&0].state == SeqState::Running {
            break;
        }
        assert!(s.bm.check_conservation());
    }
    assert_eq!(bounds, vec![(0, 4), (4, 8), (8, 12), (12, 16)]);
}

#[test]
fn cache_hit_lands_mid_chunk() {
    // A 8-token cached prefix with a 20-token chunk budget: the first
    // chunk must start exactly at the hit (not 0, not a chunk multiple)
    // and share the hit blocks.
    let bs = 4;
    let mut s = Scheduler::new(
        EngineConfig {
            max_running: 2,
            max_batch_tokens: 64,
            decode_batches: vec![1, 2],
            prefill_buckets: vec![(4, 64)],
            max_prefill_chunk: 20,
            ..Default::default()
        },
        BlockManager::new(bs, 32),
    );
    s.bm.watermark_blocks = 0;
    let prefix: Vec<u32> = (0..8).collect();
    let mut donor = prefix.clone();
    donor.extend([100, 101]);
    let mut warm = prefix.clone();
    warm.extend((0..14u32).map(|t| 200 + t)); // 22 tokens total
    let mut seqs = HashMap::new();
    seqs.insert(0, Sequence::new(0, donor.clone(),
                                 SamplingParams::default()));
    seqs.insert(1, Sequence::new(1, warm.clone(),
                                 SamplingParams::default()));
    s.add(0);
    let plan = s.plan(&seqs);
    assert_eq!(plan.chunks.len(), 1);
    seqs.get_mut(&0).unwrap().prefill_progress = plan.chunks[0].end;
    seqs.get_mut(&0).unwrap().state = SeqState::Running;
    s.bm.register_prefix(0, &donor);
    s.on_finished(0);
    s.add(1);
    let plan = s.plan(&seqs);
    assert_eq!(plan.chunks.len(), 1);
    let c = &plan.chunks[0];
    assert!(c.admitted);
    // 2 full blocks of the shared prefix are cached -> hit = 8
    assert_eq!(c.start, 8);
    // chunk cap 20 from position 8 would reach 28 but clamps to target
    assert_eq!(c.end, 22);
    assert!(s.bm.check_conservation());
}

#[test]
fn grown_content_beyond_pool_drops_instead_of_wedging() {
    // Regression (found in PR 3 review): sequence B's recompute content
    // (prompt + generated output) outgrows the *whole* pool after a
    // preemption. Pre-fix, B was requeued and its re-admission failed
    // forever — the FCFS head wedged with has_work() true and every
    // plan idle. Now the impossible head is dropped (PoolExhausted) and
    // traffic drains.
    let mut s = Scheduler::new(
        EngineConfig {
            max_running: 4,
            max_batch_tokens: 256,
            decode_batches: vec![1, 2, 4],
            prefill_buckets: vec![(4, 64)],
            ..Default::default()
        },
        BlockManager::new(4, 6), // 24 token slots
    );
    s.bm.watermark_blocks = 1;
    let mut seqs = HashMap::new();
    seqs.insert(
        0,
        Sequence::new(0, vec![1, 2, 3, 4], SamplingParams {
            max_new_tokens: 20,
            ..Default::default()
        }),
    );
    seqs.insert(
        1,
        Sequence::new(1, (10..22).collect(), SamplingParams {
            max_new_tokens: 16, // content would reach 28 > 24 slots
            ..Default::default()
        }),
    );
    s.add(0);
    s.add(1);
    let mut steps = 0;
    while s.has_work() && steps < 2000 {
        let plan = s.plan(&seqs);
        for &v in &s.preempted {
            let q = seqs.get_mut(&v).unwrap();
            if q.state == SeqState::Running
                || q.state == SeqState::Prefilling
            {
                q.preempt();
            }
        }
        for &v in &s.dropped {
            seqs.get_mut(&v).unwrap()
                .finish(FinishReason::PoolExhausted);
        }
        for c in &plan.chunks {
            let toks = seqs[&c.id].full_tokens();
            let q = seqs.get_mut(&c.id).unwrap();
            q.prefill_progress = c.end;
            if c.end == toks.len() {
                q.state = SeqState::Running;
                q.record_token(7);
            } else {
                q.state = SeqState::Prefilling;
            }
            s.bm.register_prefix(c.id, &toks[..c.end]);
        }
        for &id in &plan.decode {
            let q = seqs.get_mut(&id).unwrap();
            q.record_token(7);
            if q.output.len() >= q.params.max_new_tokens {
                q.finish(FinishReason::MaxTokens);
                s.on_finished(id);
            }
        }
        assert!(s.bm.check_conservation());
        steps += 1;
    }
    assert!(!s.has_work(), "scheduler wedged after {steps} steps");
    assert_eq!(seqs[&0].finish, Some(FinishReason::MaxTokens));
    assert_eq!(seqs[&0].output.len(), 20);
    assert_eq!(seqs[&1].finish, Some(FinishReason::PoolExhausted));
}

#[test]
fn single_walk_admission_matches_reference_double_walk() {
    // The PR 4 admission contract: one allocator call per attempt does
    // the walk, the capacity check, and the allocation, returning the
    // hit it honored. Against a pre-plan snapshot of the block manager
    // (ample pool, so no mid-plan eviction mutates the cache) the old
    // double-walk probe must agree with every admitted chunk's start —
    // i.e. single-walk admission never over- or under-budgets relative
    // to the reference — and the walk counter must not exceed one walk
    // per attempt (admissions + at most one rejected head).
    for (chunked, chunk) in [(true, 0usize), (true, 6), (false, 0)] {
        prop::check("single-walk admission", 8, |rng| {
            let bs = 2 + rng.below(4);
            let prefixes = shared_prefixes(bs);
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 2 + rng.below(4),
                    max_batch_tokens: 24 + rng.below(64),
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 64)],
                    enable_chunked_prefill: chunked,
                    max_prefill_chunk: chunk,
                    ..Default::default()
                },
                BlockManager::new(bs, 512), // ample: no eviction
            );
            let mut seqs = HashMap::new();
            let mut next_id = 0u64;
            for _ in 0..300 {
                if next_id < 30 && rng.below(2) == 0 {
                    let p = prompt(rng, &prefixes, next_id as u32);
                    seqs.insert(
                        next_id,
                        Sequence::new(next_id, p,
                                      SamplingParams::default()),
                    );
                    s.add(next_id);
                    next_id += 1;
                }
                let snap = s.bm.clone();
                let walks_before = s.bm.hash_walks.get();
                let plan = s.plan(&seqs);
                let walks = s.bm.hash_walks.get() - walks_before;
                let admitted: Vec<_> =
                    plan.chunks.iter().filter(|c| c.admitted).collect();
                // at most one walk per admission attempt: every
                // admission walks once, plus at most one walk for the
                // head whose attempt was rejected (the loop breaks)
                assert!(
                    walks <= admitted.len() as u64 + 1,
                    "{walks} walks for {} admissions",
                    admitted.len()
                );
                for c in &admitted {
                    let toks = seqs[&c.id].full_tokens();
                    assert_eq!(
                        c.start,
                        snap.cached_prefix_tokens(&toks),
                        "allocator hit diverged from reference probe"
                    );
                }
                // budget accounting over the returned hits: chunk
                // tokens never exceed the step budget left by decodes
                // (floored at one schedulable chunk token)
                if chunked {
                    let chunk_tokens: usize = plan
                        .chunks
                        .iter()
                        .map(|c| c.end - c.start)
                        .sum();
                    let floor = s
                        .cfg
                        .max_batch_tokens
                        .saturating_sub(plan.decode.len())
                        .max(1);
                    assert!(
                        chunk_tokens <= floor,
                        "over-budget: {chunk_tokens} > {floor}"
                    );
                }
                // drive the engine side so the workload progresses
                for c in &plan.chunks {
                    let toks = seqs[&c.id].full_tokens();
                    let q = seqs.get_mut(&c.id).unwrap();
                    q.prefill_progress = c.end;
                    if c.end == toks.len() {
                        q.state = SeqState::Running;
                        q.record_token(fake_next_token(&toks));
                    } else {
                        q.state = SeqState::Prefilling;
                    }
                    s.bm.register_prefix(c.id, &toks[..c.end]);
                }
                for id in plan.decode.clone() {
                    let q = seqs.get_mut(&id).unwrap();
                    q.record_token(fake_next_token(&q.full_tokens()));
                    if q.output.len() >= 3 + (id % 4) as usize {
                        q.finish(FinishReason::MaxTokens);
                        s.on_finished(id);
                    }
                }
                assert!(s.bm.check_conservation());
            }
        });
    }
}

/// Run `prompts` one at a time to completion on a FakeCore with the
/// given tiered-pool bound and stash precision, asserting pool
/// occupancy never exceeds the bound. Returns the core (for counter
/// probes) and the per-request token streams.
fn run_fake_sequential(bs: usize, total_blocks: usize, pool: usize,
                       mode: KvCacheMode, prompts: &[Vec<u32>])
    -> (FakeCore, Vec<Vec<u32>>) {
    let mut core = FakeCore::new(
        EngineConfig {
            block_size: bs,
            kv_pool_blocks: pool,
            kv_cache_mode: mode,
            ..Default::default()
        },
        total_blocks,
    );
    let mut streams = vec![];
    for p in prompts {
        let id = core
            .submit(p.clone(), SamplingParams {
                max_new_tokens: 1,
                ..Default::default()
            })
            .unwrap();
        let mut guard = 0;
        loop {
            core.step().unwrap();
            assert!(core.sched.bm.kv_pool_len() <= pool,
                    "pool occupancy exceeded its bound");
            if let Some(q) = core.take_finished().pop() {
                assert_eq!(q.id, id);
                assert_eq!(q.finish, Some(FinishReason::MaxTokens));
                streams.push(q.output.clone());
                break;
            }
            guard += 1;
            assert!(guard < 500, "request {id} never finished");
        }
    }
    (core, streams)
}

/// An evict-then-rehit trace: request `a` seeds shared prefix `P`, a
/// pool-filling stranger evicts every cached block, then `c` reuses
/// `P`. With tiering the eviction demotes instead of dropping, so `c`
/// restores `P` from the pool.
fn evict_then_rehit_trace(rng: &mut Rng, bs: usize, pblocks: usize,
                          total_blocks: usize) -> Vec<Vec<u32>> {
    let prefix: Vec<u32> = (0..(pblocks * bs) as u32).collect();
    let mut a = prefix.clone();
    a.extend((0..(1 + rng.below(bs)) as u32).map(|t| 2000 + t));
    // needs exactly every device block, so admission demand-evicts all
    // cached content
    let filler: Vec<u32> =
        (0..(total_blocks * bs - 1) as u32).map(|t| 5000 + t).collect();
    let mut c = prefix.clone();
    c.extend((0..(1 + rng.below(bs)) as u32).map(|t| 3000 + t));
    vec![a, filler, c]
}

#[test]
fn tiered_pool_restores_strictly_reduce_prefill_work() {
    // The tiering contract: on an evict-then-rehit trace, the demoted
    // prefix is restored from the pool instead of recomputed — strictly
    // fewer prefill tokens executed than the identical untiered run,
    // identical token streams, restore counters exactly accounting the
    // saving, and the pool bound held at every step.
    prop::check("tiered restore saves prefill", 8, |rng| {
        let bs = 2 + rng.below(4);
        let pblocks = 1 + rng.below(3);
        let total = pblocks + 2 + rng.below(3);
        let pool = pblocks + 2 + rng.below(3);
        let prompts = evict_then_rehit_trace(rng, bs, pblocks, total);
        let (cold, cold_streams) =
            run_fake_sequential(bs, total, 0, KvCacheMode::F32, &prompts);
        let (warm, warm_streams) =
            run_fake_sequential(bs, total, pool, KvCacheMode::F32,
                                &prompts);
        // streams are a pure function of content — tiering must not
        // change what is computed, only how much
        assert_eq!(cold_streams, warm_streams);
        let cs = cold.core_stats();
        let ws = warm.core_stats();
        assert_eq!(cs.cache.demotions, 0);
        assert_eq!(cs.recompute_avoided_tokens, 0);
        assert!(ws.cache.restores > 0, "rehit never restored");
        assert!(ws.cache.demotions > 0, "eviction never demoted");
        // every restore skips exactly one block of prefill
        assert_eq!(ws.recompute_avoided_tokens,
                   ws.cache.restores * bs);
        // executed + cached partitions the same prompt tokens in both
        // runs; the tiered run just moved tokens from one side to the
        // other — and the moved amount is exactly the restore accounting
        assert_eq!(ws.prefill_tokens_executed + ws.cached_prefix_tokens,
                   cs.prefill_tokens_executed + cs.cached_prefix_tokens);
        assert_eq!(ws.cached_prefix_tokens - cs.cached_prefix_tokens,
                   ws.recompute_avoided_tokens);
        assert!(ws.prefill_tokens_executed
                    < cs.prefill_tokens_executed,
                "tiering saved nothing: {} vs {}",
                ws.prefill_tokens_executed, cs.prefill_tokens_executed);
        assert!(warm.sched.bm.check_conservation());
    });
}

#[test]
fn teardown_clears_tiered_pool_and_forgets_demoted_blocks() {
    // Regression (replica teardown): a killed replica's demoted blocks
    // must not survive `drain_inflight` — a later identical request
    // recomputes from scratch instead of restoring stale content.
    prop::check("teardown clears pool", 6, |rng| {
        let bs = 2 + rng.below(4);
        let pblocks = 1 + rng.below(3);
        let total = pblocks + 2 + rng.below(3);
        let pool = pblocks + 2 + rng.below(3);
        let prompts = evict_then_rehit_trace(rng, bs, pblocks, total);
        // populate the pool: seed + evict, but stop before the rehit
        let (mut core, _) =
            run_fake_sequential(bs, total, pool, KvCacheMode::F32,
                                &prompts[..2]);
        assert!(core.sched.bm.kv_pool_len() > 0,
                "trace never demoted (test too weak)");
        core.drain_inflight();
        assert_eq!(core.sched.bm.kv_pool_len(), 0,
                   "teardown leaked demoted blocks");
        assert!(core.sched.bm.check_conservation());
        // the rehit now finds nothing: no restore may fire
        let restores_before = core.sched.bm.stats.restores;
        let id = core
            .submit(prompts[2].clone(), SamplingParams {
                max_new_tokens: 1,
                ..Default::default()
            })
            .unwrap();
        let mut fin = None;
        for _ in 0..500 {
            core.step().unwrap();
            if let Some(q) = core.take_finished().pop() {
                fin = Some(q);
                break;
            }
        }
        let fin = fin.expect("post-teardown request never finished");
        assert_eq!(fin.id, id);
        assert_eq!(core.sched.bm.stats.restores, restores_before,
                   "restored a block the teardown should have dropped");
        // and the recomputed stream is still the content-determined one
        assert_eq!(fin.output, vec![fake_next_token(&prompts[2])]);
    });
}

#[test]
fn kv_quant_mode_never_perturbs_fake_streams() {
    // The satellite gate "Q8/Q4 within tolerance of F32 on the
    // deterministic fake model" — the fake core holds no KV bytes, so
    // the tolerance is exact: the stash-precision knob must change
    // nothing at this layer (streams, prefill/cache accounting, pool
    // traffic). Any drift means quantization leaked into *scheduling*,
    // which only the engine's stash encode/decode may feel.
    prop::check("kv mode is scheduling-invariant", 6, |rng| {
        let bs = 2 + rng.below(4);
        let pblocks = 1 + rng.below(3);
        let total = pblocks + 2 + rng.below(3);
        let pool = pblocks + 2 + rng.below(3);
        let prompts = evict_then_rehit_trace(rng, bs, pblocks, total);
        let mut golden: Option<(Vec<Vec<u32>>, usize, usize, usize)> =
            None;
        for mode in
            [KvCacheMode::F32, KvCacheMode::Q8, KvCacheMode::Q4]
        {
            let (core, streams) =
                run_fake_sequential(bs, total, pool, mode, &prompts);
            let s = core.core_stats();
            let probe = (streams, s.prefill_tokens_executed,
                         s.cached_prefix_tokens, s.cache.restores);
            match &golden {
                None => golden = Some(probe),
                Some(g) => assert_eq!(
                    g, &probe,
                    "kv mode {mode:?} perturbed the fake run"
                ),
            }
        }
        // the trace must actually exercise the tier for the
        // invariance to mean anything
        assert!(golden.unwrap().3 > 0, "trace never restored");
    });
}

#[test]
fn token_streams_identical_for_any_chunk_size() {
    // The determinism property: with the deterministic fake model, the
    // same submission schedule must produce identical per-sequence
    // token streams whatever the chunking (including legacy mode) —
    // chunking changes *when* work happens, never *what* is computed.
    prop::check("chunk-size determinism", 6, |rng| {
        let bs = 2 + rng.below(4);
        let prefixes = shared_prefixes(bs);
        let seed = rng.below(1 << 30) as u64;
        let blocks = 24 + rng.below(48);
        let mut streams: Vec<Vec<(u64, Vec<u32>)>> = vec![];
        for (chunked, chunk) in
            [(false, 0usize), (true, 0), (true, 17), (true, 3)]
        {
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 3,
                    max_batch_tokens: 48,
                    decode_batches: vec![1, 2, 4],
                    prefill_buckets: vec![(4, 64)],
                    enable_chunked_prefill: chunked,
                    max_prefill_chunk: chunk,
                    ..Default::default()
                },
                BlockManager::new(bs, blocks),
            );
            let mut seqs = HashMap::new();
            let mut r = Rng::new(seed);
            drive(&mut s, &mut seqs, &mut r, 3000, 16, &prefixes);
            assert!(!s.has_work(), "did not drain");
            let mut out: Vec<(u64, Vec<u32>)> = seqs
                .iter()
                .filter(|(_, q)| q.finish == Some(FinishReason::MaxTokens))
                .map(|(&id, q)| (id, q.output.clone()))
                .collect();
            out.sort_by_key(|(id, _)| *id);
            streams.push(out);
        }
        for other in &streams[1..] {
            assert_eq!(&streams[0], other,
                       "token stream depends on chunking");
        }
    });
}
