//! Fixture: a whole-file waiver. Must produce zero findings.

// sqlint: allow-file(panic) fixture: test-double file, panics are injected faults

pub fn f(o: Option<u32>) -> u32 {
    o.unwrap()
}

pub fn g(o: Option<u32>) -> u32 {
    o.expect("still covered by the file-level marker")
}
