//! Fixture: every panic positive suppressed by a justified marker
//! (standalone and trailing forms). Must produce zero findings.

use std::collections::HashMap;

pub fn f(m: &HashMap<u64, u32>, o: Option<u32>) -> u32 {
    // sqlint: allow(panic) fixture: a standalone marker covers the next line
    let a = o.unwrap();
    let b = o.expect("present"); // sqlint: allow(panic) fixture: trailing marker
    if a > b {
        // sqlint: allow(panic) fixture: justified macro
        panic!("boom");
    }
    // sqlint: allow(panic) fixture: map index on a known-live key
    m[&a]
}
