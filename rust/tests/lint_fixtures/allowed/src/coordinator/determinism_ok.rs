//! Fixture: determinism positives that are exempt — by marker, by an
//! order-insensitive adaptor chain, or by a `.sort` within the
//! 20-line lookahead. Must produce zero findings.

use std::collections::HashMap;

pub struct S {
    reqs: HashMap<u64, u32>,
}

impl S {
    pub fn f(&self) -> Vec<u64> {
        // sqlint: allow(determinism) fixture: wall-clock stamp is metrics-only
        let _t = std::time::Instant::now();
        // order-insensitive consumer: no marker needed
        let _n = self.reqs.keys().count();
        // sorted immediately below: the lookahead exempts this
        let mut ids: Vec<u64> = self.reqs.keys().copied().collect();
        ids.sort_unstable();
        // sqlint: allow(determinism) fixture: commutative fold over values
        for (_k, _v) in &self.reqs {
            let _ = _k;
        }
        ids
    }
}
