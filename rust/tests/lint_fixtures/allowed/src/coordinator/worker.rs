//! Fixture: locks rule-B negative — the guard is dropped (block ends)
//! before the channel send. Must produce zero findings.

pub fn pump(
    m: &std::sync::Mutex<u32>,
    tx: &std::sync::mpsc::Sender<u32>,
) {
    let v = {
        let g = match m.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        *g
    };
    tx.send(v).ok();
}
