//! Allowed fixture for the `events` pass: exhaustive handling needs no
//! waiver; a deliberate catch-all carries a justified marker.

pub enum PoolEvent {
    Filled { blocks: usize },
    Drained,
}

pub fn apply(ev: &PoolEvent) -> usize {
    match ev {
        PoolEvent::Filled { blocks } => *blocks,
        PoolEvent::Drained => 0,
    }
}

pub fn filled_blocks(ev: &PoolEvent) -> usize {
    match ev {
        PoolEvent::Filled { blocks } => *blocks,
        // sqlint: allow(events) metrics-only tally; a dropped event here cannot corrupt router state
        _ => 0,
    }
}
