//! Fixture: wire-pass negative — every field reaches all three wire
//! functions, one of them only as a string-literal substring. Must
//! produce zero findings.

pub struct RouterStats {
    pub shed: usize,
    pub alive: usize,
}

pub fn stats_json(s: &RouterStats) -> String {
    format!("{{\"shed\":{},\"alive\":{}}}", s.shed, s.alive)
}

pub fn decode_stats(_line: &str) -> RouterStats {
    RouterStats { shed: 0, alive: 0 }
}

pub fn metrics_text(_s: &RouterStats) -> String {
    "sq_router_shed 0\nsq_router_alive 0\n".to_string()
}
