//! Fixture: determinism-pass positives. Scanned by
//! `tests/lint_tool.rs`, never compiled.

use std::collections::HashMap;

pub struct S {
    reqs: HashMap<u64, u32>,
}

impl S {
    pub fn f(&self) -> Vec<u64> {
        let _t = std::time::Instant::now();
        let _s = std::time::SystemTime::now();
        let _r = rand::thread_rng();
        let out: Vec<u64> = self.reqs.keys().copied().collect();
        for (_k, _v) in &self.reqs {
            let _ = _k;
        }
        out
    }
}
