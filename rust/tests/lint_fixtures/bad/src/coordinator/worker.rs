//! Fixture: locks rule-B positive — a lock guard bound by `match` and
//! still held across a channel `.send()`. Scanned by
//! `tests/lint_tool.rs`, never compiled. Named `worker.rs` under
//! `coordinator/` because rule B only fires there and in `server/`.

pub fn pump(
    m: &std::sync::Mutex<Vec<u32>>,
    tx: &std::sync::mpsc::Sender<u32>,
) {
    let g = match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    tx.send(g[0]).ok();
}
