//! Bad fixture for the `events` pass: wildcard and catch-all binding
//! arms in `match` expressions over an event enum.

pub enum ReplicaEvent {
    Started { id: usize },
    Stepped { tokens: usize },
    Dead,
}

pub fn tally(ev: &ReplicaEvent) -> usize {
    match ev {
        ReplicaEvent::Stepped { tokens } => *tokens,
        _ => 0,
    }
}

pub fn describe(ev: &ReplicaEvent) -> &'static str {
    match ev {
        ReplicaEvent::Started { .. } => "started",
        other if matches!(other, ReplicaEvent::Dead) => "dead",
        other => "ignored",
    }
}
