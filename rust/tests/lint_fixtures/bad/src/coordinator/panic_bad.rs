//! Fixture: panic-pass positives. Scanned by `tests/lint_tool.rs`,
//! never compiled — the counts here are pinned by that test.

use std::collections::HashMap;

pub fn f(m: &HashMap<u64, u32>, o: Option<u32>) -> u32 {
    let a = o.unwrap();
    let b = o.expect("present");
    if a > b {
        panic!("boom");
    }
    match a {
        0 => unreachable!(),
        _ => {}
    }
    // a marker with no justification is itself a finding (and does not
    // suppress the line it decorates)
    let c = o.unwrap(); // sqlint: allow(panic)
    m[&c]
}
