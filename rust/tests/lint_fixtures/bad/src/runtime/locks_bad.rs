//! Fixture: locks rule-A positives (`.lock().unwrap()` anywhere under
//! `src/`). Scanned by `tests/lint_tool.rs`, never compiled. Lives
//! under `runtime/` so the panic pass (coordinator/server scope) does
//! not double-count the unwrap.

pub fn f(m: &std::sync::Mutex<u32>) -> u32 {
    let g = m.lock().unwrap();
    *g
}

pub fn h(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().expect("poisoned")
}
