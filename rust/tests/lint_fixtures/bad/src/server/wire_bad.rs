//! Fixture: wire-pass positives — `dropped_total` reaches
//! `decode_stats` but not `stats_json` or `metrics_text`. Scanned by
//! `tests/lint_tool.rs`, never compiled.

pub struct CoreStats {
    pub waiting: usize,
    pub dropped_total: usize,
}

pub fn stats_json(s: &CoreStats) -> String {
    format!("{{\"waiting\":{}}}", s.waiting)
}

pub fn decode_stats(_line: &str) -> CoreStats {
    CoreStats { waiting: 0, dropped_total: 0 }
}

pub fn metrics_text(s: &CoreStats) -> String {
    format!("sq_waiting {}\n", s.waiting)
}
