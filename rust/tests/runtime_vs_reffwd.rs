//! Integration: the PJRT-executed HLO (lowered from JAX, with and without
//! the Pallas W4A16 kernel) must match the pure-Rust reference forward on
//! the same weights — the cross-language, cross-layer numerics check.
//!
//! Requires `make artifacts`. Tests skip (with a note) if absent.

use sqplus::config::{ModelConfig, Precision, QuantConfig, QuantMethod};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::reffwd::{NoHook, RefModel};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::kv::{self, SeqKv};
use sqplus::runtime::manifest::{default_dir, Manifest};
use sqplus::util::prop;

fn manifest() -> Option<Manifest> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (make artifacts)");
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    let scale = b.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1e-6);
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
        / scale
}

#[test]
fn fp16_prefill_matches_reference() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();

    let prompt: Vec<u32> = vec![5, 9, 2, 7, 1, 4, 6, 8];
    let res = rt.prefill(&[&prompt]).unwrap();
    let (want, _) = RefModel::new(&cfg, &w).prefill(&prompt, &mut NoHook);

    // compare logits at every real position
    for pos in 0..prompt.len() {
        let got =
            &res.logits[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let e = max_rel_err(got, want.row(pos));
        assert!(e < 1e-3, "pos {pos}: rel err {e}");
    }
}

#[test]
fn fp16_decode_matches_reference() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();

    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5];
    // runtime path: prefill then 3 decode steps
    let pre = rt.prefill(&[&prompt]).unwrap();
    let mut seq = SeqKv::new(&cfg);
    {
        let mut refs = [&mut seq];
        kv::fill_prefill_rows(&mut refs, &cfg, pre.batch, pre.seq,
                              &pre.kv_new, &[prompt.len()]);
    }
    // reference path
    let rm = RefModel::new(&cfg, &w);
    let (_, mut rcache) = rm.prefill(&prompt, &mut NoHook);

    let next = [9u32, 2, 6];
    for &t in &next {
        let kvb = kv::assemble_batch(&[&seq], &cfg, 1);
        let got = rt.decode(&[t], &[seq.len], &kvb).unwrap();
        {
            let mut refs = [&mut seq];
            kv::append_decode_rows(&mut refs, &cfg, got.batch, &got.kv_new);
        }
        let want = rm.decode(t, &mut rcache, &mut NoHook);
        let e = max_rel_err(&got.logits[..cfg.vocab], &want);
        assert!(e < 1e-3, "token {t}: rel err {e}");
    }
}

#[test]
fn w4a16_runtime_matches_fake_quant_reference() {
    // The Pallas kernel path (packed weights through PJRT) must equal the
    // Rust fake-quant reference — this closes the loop on the shared
    // quantization numerics.
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::with_outliers(1, 4, 40.0));
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..10u32).map(|t| (i * 97 + t * 31) % 512).collect())
        .collect();
    let cal = calib::collect(&cfg, &w, &prompts, 24, 0);
    let out = pipeline::quantize_model(&cfg, &w, &cal,
                                       QuantMethod::SmoothQuantPlus,
                                       &QuantConfig::default());
    let rt = ModelRuntime::load(&m, "tiny", Precision::W4a16,
                                out.deploy.as_ref().unwrap())
        .unwrap();

    let prompt: Vec<u32> = vec![11, 22, 33, 44, 55, 66];
    let res = rt.prefill(&[&prompt]).unwrap();
    let (want, _) =
        RefModel::new(&cfg, &out.effective).prefill(&prompt, &mut NoHook);
    for pos in [0usize, 3, 5] {
        let got = &res.logits[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let e = max_rel_err(got, want.row(pos));
        assert!(e < 2e-3, "pos {pos}: rel err {e}");
    }
}

#[test]
fn batched_prefill_slots_are_independent() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    let p1: Vec<u32> = vec![10, 20, 30];
    let p2: Vec<u32> = vec![400, 52, 77, 8, 123];
    let solo = rt.prefill(&[&p1]).unwrap();
    let both = rt.prefill(&[&p1, &p2]).unwrap();
    // p1 logits identical whether batched with p2 or not
    for pos in 0..p1.len() {
        let a = &solo.logits[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let b = &both.logits[(0 * both.seq + pos) * cfg.vocab..][..cfg.vocab];
        prop::assert_allclose(a, b, 1e-4, 1e-5, "batch independence");
    }
}

#[test]
fn decode_bucket_padding_is_inert() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5];
    let pre = rt.prefill(&[&prompt]).unwrap();
    let mut seq = SeqKv::new(&cfg);
    {
        let mut refs = [&mut seq];
        kv::fill_prefill_rows(&mut refs, &cfg, pre.batch, pre.seq,
                              &pre.kv_new, &[prompt.len()]);
    }
    // run the same decode through bucket 1 and bucket 2 (padded)
    let kv1 = kv::assemble_batch(&[&seq], &cfg, 1);
    let a = rt.decode(&[7], &[seq.len], &kv1).unwrap();
    let kv2 = kv::assemble_batch(&[&seq], &cfg, 2);
    let b = rt.decode(&[7, 0], &[seq.len, 0], &kv2).unwrap();
    prop::assert_allclose(&a.logits[..cfg.vocab], &b.logits[..cfg.vocab],
                          1e-4, 1e-5, "padding inert");
}
