//! End-to-end engine integration: requests through scheduler → block
//! manager → PJRT runtime → sampler, for FP16 and SmoothQuant+ W4A16.
//! Requires `make artifacts` (tests skip otherwise).

use sqplus::config::{
    EngineConfig, GpuProfile, ModelConfig, Precision, QuantConfig,
    QuantMethod,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::{FinishReason, SamplingParams};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::manifest::{default_dir, Manifest};
use sqplus::runtime::simtp::Deployment;

fn manifest() -> Option<Manifest> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (make artifacts)");
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

fn fp16_engine(m: &Manifest, ecfg: EngineConfig) -> Engine {
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    Engine::new(Deployment::single(rt, GpuProfile::sim_small(64)), ecfg)
}

#[test]
fn serves_batch_of_requests_to_completion() {
    let Some(m) = manifest() else { return };
    let mut eng = fp16_engine(&m, EngineConfig::default());
    let mut ids = vec![];
    for i in 0..6u32 {
        let prompt: Vec<u32> =
            (0..5 + i % 3).map(|t| (i * 53 + t * 17) % 512).collect();
        ids.push(eng.submit(
            prompt,
            SamplingParams { max_new_tokens: 6, ..Default::default() },
        ));
    }
    let steps = eng.run_to_completion(500).unwrap();
    assert!(steps < 500, "did not converge");
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 6);
    for f in &fin {
        assert_eq!(f.finish, Some(FinishReason::MaxTokens));
        assert_eq!(f.output.len(), 6);
    }
    let rep = eng.metrics.report();
    assert_eq!(rep.requests_done, 6);
    assert_eq!(rep.output_tokens, 36);
}

#[test]
fn greedy_engine_matches_reference_generation() {
    // engine-generated tokens == greedy generation on the reference model
    let Some(m) = manifest() else { return };
    use sqplus::coordinator::sampler::argmax;
    use sqplus::reffwd::{NoHook, RefModel};

    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    let mut eng = Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(64)),
        EngineConfig::default(),
    );
    let prompt: Vec<u32> = vec![17, 301, 5, 99];
    let id = eng.submit(
        prompt.clone(),
        SamplingParams { max_new_tokens: 5, ..Default::default() },
    );
    eng.run_to_completion(100).unwrap();
    let fin = eng.take_finished();
    let got = &fin.iter().find(|s| s.id == id).unwrap().output;

    // reference greedy loop
    let rm = RefModel::new(&cfg, &w);
    let (logits, mut cache) = rm.prefill(&prompt, &mut NoHook);
    let mut want = vec![argmax(logits.row(prompt.len() - 1))];
    for _ in 0..4 {
        let lg = rm.decode(*want.last().unwrap(), &mut cache, &mut NoHook);
        want.push(argmax(&lg));
    }
    assert_eq!(got, &want);
}

#[test]
fn w4a16_quantized_engine_serves() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 40.0));
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..10u32).map(|t| (i * 97 + t * 31) % 512).collect())
        .collect();
    let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
    let out = pipeline::quantize_model(&cfg, &w, &cal,
                                       QuantMethod::SmoothQuantPlus,
                                       &QuantConfig::default());
    let rt = ModelRuntime::load(&m, "tiny", Precision::W4a16,
                                out.deploy.as_ref().unwrap())
        .unwrap();
    let mut eng = Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(64)),
        EngineConfig::default(),
    );
    for i in 0..4u32 {
        eng.submit(
            (0..6).map(|t| (i * 7 + t) % 512).collect(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
    }
    eng.run_to_completion(200).unwrap();
    assert_eq!(eng.take_finished().len(), 4);
}

#[test]
fn preemption_under_tiny_pool_still_completes_everything() {
    let Some(m) = manifest() else { return };
    // KV pool so small that concurrent sequences must preempt
    let ecfg = EngineConfig {
        block_size: 4,
        total_blocks: 14,
        max_running: 4,
        ..Default::default()
    };
    let mut eng = fp16_engine(&m, ecfg);
    for i in 0..5u32 {
        eng.submit(
            (0..8).map(|t| (i * 13 + t) % 512).collect(),
            SamplingParams { max_new_tokens: 8, ..Default::default() },
        );
    }
    eng.run_to_completion(1000).unwrap();
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 5);
    for f in &fin {
        assert_eq!(f.output.len(), 8, "seq {} output {:?}", f.id, f.output);
    }
    // under this pool pressure at least one preemption should occur
    let rep = eng.metrics.report();
    assert!(rep.preemptions > 0, "expected preemption pressure");
}

#[test]
fn preempted_sequences_continue_deterministically() {
    // with greedy sampling, preemption + recompute must not change output
    let Some(m) = manifest() else { return };
    let prompts: Vec<Vec<u32>> = (0..5)
        .map(|i| (0..8u32).map(|t| (i * 13 + t) % 512).collect())
        .collect();
    let gen = |ecfg: EngineConfig| {
        let mut eng = fp16_engine(&m, ecfg);
        for p in &prompts {
            eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 8, ..Default::default() },
            );
        }
        eng.run_to_completion(1000).unwrap();
        let mut fin = eng.take_finished();
        fin.sort_by_key(|s| s.id);
        fin.iter().map(|s| s.output.clone()).collect::<Vec<_>>()
    };
    let relaxed = gen(EngineConfig::default());
    let pressured = gen(EngineConfig {
        block_size: 4,
        total_blocks: 14,
        max_running: 4,
        ..Default::default()
    });
    assert_eq!(relaxed, pressured);
}

#[test]
fn prefix_cache_golden_identical_streams_fewer_prefill_tokens() {
    // Determinism golden test: the same seeded request trace through a
    // cold-cache engine (prefix caching off) and a warm engine (caching
    // on, requests share a prefix so later ones hit blocks registered
    // by earlier ones) must emit bit-for-bit identical token streams —
    // prefix reuse never changes sampling results — while the warm
    // engine executes strictly fewer prefill tokens.
    //
    // Like `preempted_sequences_continue_deterministically` below, this
    // relies on the prefill and decode executables agreeing at greedy-
    // argmax level for the same context (the repo's standing recompute
    // assumption); the cached KV rows themselves are bit-identical
    // copies of the donor's.
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(42);
    let prefix: Vec<u32> =
        (0..16).map(|_| (1 + rng.below(511)) as u32).collect();
    let prompts: Vec<Vec<u32>> = (0..6u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..4u32).map(|t| (i * 37 + t * 11 + 1) % 512));
            p
        })
        .collect();
    let run = |enable: bool| {
        let ecfg = EngineConfig {
            block_size: 4,
            enable_prefix_caching: enable,
            ..Default::default()
        };
        let mut eng = fp16_engine(&m, ecfg);
        let mut outs = vec![];
        // submit sequentially so later requests can hit the blocks the
        // earlier ones registered
        for p in &prompts {
            let id = eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 6, ..Default::default() },
            );
            eng.run_to_completion(500).unwrap();
            let fin = eng.take_finished();
            let seq = fin.into_iter().find(|s| s.id == id).unwrap();
            outs.push((seq.output.clone(), seq.cached_prefix_len));
        }
        let stats = eng.cache_stats();
        (outs, eng.metrics.prefill_tokens_executed,
         eng.metrics.cached_prefix_tokens, stats)
    };
    let (cold, cold_exec, cold_hit, cold_stats) = run(false);
    let (warm, warm_exec, warm_hit, warm_stats) = run(true);
    // identical token streams, bit for bit
    let cold_tokens: Vec<&Vec<u32>> =
        cold.iter().map(|(o, _)| o).collect();
    let warm_tokens: Vec<&Vec<u32>> =
        warm.iter().map(|(o, _)| o).collect();
    assert_eq!(cold_tokens, warm_tokens);
    // the cold engine computed everything; the warm one reused blocks
    assert_eq!(cold_hit, 0);
    assert_eq!(cold_stats.hits, 0);
    assert!(warm_hit > 0, "no cached prefix tokens");
    assert!(warm_stats.hits > 0);
    assert!(warm_exec < cold_exec,
            "warm prefill executed {warm_exec} !< cold {cold_exec}");
    // every request after the first reported its cached prefix
    assert_eq!(warm[0].1, 0);
    for (_, c) in &warm[1..] {
        assert_eq!(*c, 16, "expected a full shared-prefix hit");
    }
}

#[test]
fn rejects_overlong_prompt() {
    let Some(m) = manifest() else { return };
    let mut eng = fp16_engine(&m, EngineConfig::default());
    let long: Vec<u32> = vec![1; 4096];
    eng.submit(long, SamplingParams::default());
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].finish, Some(FinishReason::PromptTooLong));
}
