//! End-to-end engine integration: requests through scheduler → block
//! manager → PJRT runtime → sampler, for FP16 and SmoothQuant+ W4A16.
//! Requires `make artifacts` (tests skip otherwise).

use sqplus::config::{
    CacheWatermarks, EngineConfig, GpuProfile, KvCacheMode,
    ModelConfig, Precision, QuantConfig, QuantMethod, RouterConfig,
    RoutingPolicy,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::router::Router;
use sqplus::coordinator::sequence::{FinishReason, SamplingParams};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::manifest::{default_dir, Manifest};
use sqplus::runtime::simtp::Deployment;

fn manifest() -> Option<Manifest> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (make artifacts)");
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

fn fp16_engine(m: &Manifest, ecfg: EngineConfig) -> Engine {
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    Engine::new(Deployment::single(rt, GpuProfile::sim_small(64)), ecfg)
}

#[test]
fn serves_batch_of_requests_to_completion() {
    let Some(m) = manifest() else { return };
    let mut eng = fp16_engine(&m, EngineConfig::default());
    let mut ids = vec![];
    for i in 0..6u32 {
        let prompt: Vec<u32> =
            (0..5 + i % 3).map(|t| (i * 53 + t * 17) % 512).collect();
        ids.push(eng.submit(
            prompt,
            SamplingParams { max_new_tokens: 6, ..Default::default() },
        ));
    }
    let steps = eng.run_to_completion(500).unwrap();
    assert!(steps < 500, "did not converge");
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 6);
    for f in &fin {
        assert_eq!(f.finish, Some(FinishReason::MaxTokens));
        assert_eq!(f.output.len(), 6);
    }
    let rep = eng.metrics.report();
    assert_eq!(rep.requests_done, 6);
    assert_eq!(rep.output_tokens, 36);
}

#[test]
fn greedy_engine_matches_reference_generation() {
    // engine-generated tokens == greedy generation on the reference model
    let Some(m) = manifest() else { return };
    use sqplus::coordinator::sampler::argmax;
    use sqplus::reffwd::{NoHook, RefModel};

    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    let mut eng = Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(64)),
        EngineConfig::default(),
    );
    let prompt: Vec<u32> = vec![17, 301, 5, 99];
    let id = eng.submit(
        prompt.clone(),
        SamplingParams { max_new_tokens: 5, ..Default::default() },
    );
    eng.run_to_completion(100).unwrap();
    let fin = eng.take_finished();
    let got = &fin.iter().find(|s| s.id == id).unwrap().output;

    // reference greedy loop
    let rm = RefModel::new(&cfg, &w);
    let (logits, mut cache) = rm.prefill(&prompt, &mut NoHook);
    let mut want = vec![argmax(logits.row(prompt.len() - 1))];
    for _ in 0..4 {
        let lg = rm.decode(*want.last().unwrap(), &mut cache, &mut NoHook);
        want.push(argmax(&lg));
    }
    assert_eq!(got, &want);
}

#[test]
fn w4a16_quantized_engine_serves() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 40.0));
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..10u32).map(|t| (i * 97 + t * 31) % 512).collect())
        .collect();
    let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
    let out = pipeline::quantize_model(&cfg, &w, &cal,
                                       QuantMethod::SmoothQuantPlus,
                                       &QuantConfig::default());
    let rt = ModelRuntime::load(&m, "tiny", Precision::W4a16,
                                out.deploy.as_ref().unwrap())
        .unwrap();
    let mut eng = Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(64)),
        EngineConfig::default(),
    );
    for i in 0..4u32 {
        eng.submit(
            (0..6).map(|t| (i * 7 + t) % 512).collect(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
    }
    eng.run_to_completion(200).unwrap();
    assert_eq!(eng.take_finished().len(), 4);
}

#[test]
fn preemption_under_tiny_pool_still_completes_everything() {
    let Some(m) = manifest() else { return };
    // KV pool so small that concurrent sequences must preempt
    let ecfg = EngineConfig {
        block_size: 4,
        total_blocks: 14,
        max_running: 4,
        ..Default::default()
    };
    let mut eng = fp16_engine(&m, ecfg);
    for i in 0..5u32 {
        eng.submit(
            (0..8).map(|t| (i * 13 + t) % 512).collect(),
            SamplingParams { max_new_tokens: 8, ..Default::default() },
        );
    }
    eng.run_to_completion(1000).unwrap();
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 5);
    for f in &fin {
        assert_eq!(f.output.len(), 8, "seq {} output {:?}", f.id, f.output);
    }
    // under this pool pressure at least one preemption should occur
    let rep = eng.metrics.report();
    assert!(rep.preemptions > 0, "expected preemption pressure");
}

#[test]
fn preempted_sequences_continue_deterministically() {
    // with greedy sampling, preemption + recompute must not change output
    let Some(m) = manifest() else { return };
    let prompts: Vec<Vec<u32>> = (0..5)
        .map(|i| (0..8u32).map(|t| (i * 13 + t) % 512).collect())
        .collect();
    let gen = |ecfg: EngineConfig| {
        let mut eng = fp16_engine(&m, ecfg);
        for p in &prompts {
            eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 8, ..Default::default() },
            );
        }
        eng.run_to_completion(1000).unwrap();
        let mut fin = eng.take_finished();
        fin.sort_by_key(|s| s.id);
        fin.iter().map(|s| s.output.clone()).collect::<Vec<_>>()
    };
    let relaxed = gen(EngineConfig::default());
    let pressured = gen(EngineConfig {
        block_size: 4,
        total_blocks: 14,
        max_running: 4,
        ..Default::default()
    });
    assert_eq!(relaxed, pressured);
}

#[test]
fn prefix_cache_golden_identical_streams_fewer_prefill_tokens() {
    // Determinism golden test: the same seeded request trace through a
    // cold-cache engine (prefix caching off) and a warm engine (caching
    // on, requests share a prefix so later ones hit blocks registered
    // by earlier ones) must emit bit-for-bit identical token streams —
    // prefix reuse never changes sampling results — while the warm
    // engine executes strictly fewer prefill tokens.
    //
    // Like `preempted_sequences_continue_deterministically` below, this
    // relies on the prefill and decode executables agreeing at greedy-
    // argmax level for the same context (the repo's standing recompute
    // assumption); the cached KV rows themselves are bit-identical
    // copies of the donor's.
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(42);
    let prefix: Vec<u32> =
        (0..16).map(|_| (1 + rng.below(511)) as u32).collect();
    let prompts: Vec<Vec<u32>> = (0..6u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..4u32).map(|t| (i * 37 + t * 11 + 1) % 512));
            p
        })
        .collect();
    let run = |enable: bool| {
        let ecfg = EngineConfig {
            block_size: 4,
            enable_prefix_caching: enable,
            ..Default::default()
        };
        let mut eng = fp16_engine(&m, ecfg);
        let mut outs = vec![];
        // submit sequentially so later requests can hit the blocks the
        // earlier ones registered
        for p in &prompts {
            let id = eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 6, ..Default::default() },
            );
            eng.run_to_completion(500).unwrap();
            let fin = eng.take_finished();
            let seq = fin.into_iter().find(|s| s.id == id).unwrap();
            outs.push((seq.output.clone(), seq.cached_prefix_len));
        }
        let stats = eng.cache_stats();
        (outs, eng.metrics.prefill_tokens_executed,
         eng.metrics.cached_prefix_tokens, stats)
    };
    let (cold, cold_exec, cold_hit, cold_stats) = run(false);
    let (warm, warm_exec, warm_hit, warm_stats) = run(true);
    // identical token streams, bit for bit
    let cold_tokens: Vec<&Vec<u32>> =
        cold.iter().map(|(o, _)| o).collect();
    let warm_tokens: Vec<&Vec<u32>> =
        warm.iter().map(|(o, _)| o).collect();
    assert_eq!(cold_tokens, warm_tokens);
    // the cold engine computed everything; the warm one reused blocks
    assert_eq!(cold_hit, 0);
    assert_eq!(cold_stats.hits, 0);
    assert!(warm_hit > 0, "no cached prefix tokens");
    assert!(warm_stats.hits > 0);
    assert!(warm_exec < cold_exec,
            "warm prefill executed {warm_exec} !< cold {cold_exec}");
    // every request after the first reported its cached prefix
    assert_eq!(warm[0].1, 0);
    for (_, c) in &warm[1..] {
        assert_eq!(*c, 16, "expected a full shared-prefix hit");
    }
}

#[test]
fn rejects_overlong_prompt() {
    let Some(m) = manifest() else { return };
    let mut eng = fp16_engine(&m, EngineConfig::default());
    let long: Vec<u32> = vec![1; 4096];
    eng.submit(long, SamplingParams::default());
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].finish, Some(FinishReason::PromptTooLong));
}

#[test]
fn impossible_pool_request_fails_fast() {
    // a prompt whose blocks can never fit the pool must not wedge the
    // FCFS queue head forever — it fails fast with PoolExhausted and
    // traffic behind it still serves
    let Some(m) = manifest() else { return };
    let ecfg = EngineConfig {
        block_size: 4,
        total_blocks: 6, // 24 token slots
        max_running: 2,
        ..Default::default()
    };
    let mut eng = fp16_engine(&m, ecfg);
    let huge = eng.submit(
        (0..100u32).map(|t| t % 512).collect(),
        SamplingParams { max_new_tokens: 4, ..Default::default() },
    );
    let small = eng.submit(
        (0..6u32).map(|t| t + 1).collect(),
        SamplingParams { max_new_tokens: 4, ..Default::default() },
    );
    eng.run_to_completion(500).unwrap();
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 2);
    let h = fin.iter().find(|s| s.id == huge).unwrap();
    assert_eq!(h.finish, Some(FinishReason::PoolExhausted));
    let s = fin.iter().find(|s| s.id == small).unwrap();
    assert_eq!(s.output.len(), 4);
}

#[test]
fn chunked_prefill_golden_identical_streams() {
    // Engine golden test: the same trace run unchunked (legacy), with
    // chunking on but uncapped, and with chunk caps 64 and 17 must emit
    // bit-identical token streams — chunking changes *when* prefill
    // work happens, never *what* is computed. The trace mixes cold
    // long prompts (multiple chunks at cap 17), a shared prefix (warm
    // suffix chunks), and enough requests for mixed steps.
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(7);
    let prefix: Vec<u32> =
        (0..16).map(|_| (1 + rng.below(511)) as u32).collect();
    let mut prompts: Vec<Vec<u32>> = vec![];
    for i in 0..4u32 {
        // cold prompts of ~40 tokens
        prompts.push(
            (0..40u32).map(|t| (i * 53 + t * 17 + 1) % 512).collect(),
        );
        // warm prompts: shared 16-token prefix + unique suffix
        let mut p = prefix.clone();
        p.extend((0..6u32).map(|t| (i * 37 + t * 11 + 1) % 512));
        prompts.push(p);
    }
    let run = |chunked: bool, cap: usize| {
        let ecfg = EngineConfig {
            block_size: 4,
            enable_chunked_prefill: chunked,
            max_prefill_chunk: cap,
            ..Default::default()
        };
        let mut eng = fp16_engine(&m, ecfg);
        for p in &prompts {
            eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 6, ..Default::default() },
            );
        }
        eng.run_to_completion(5000).unwrap();
        let mut fin = eng.take_finished();
        fin.sort_by_key(|s| s.id);
        let outs: Vec<Vec<u32>> =
            fin.iter().map(|s| s.output.clone()).collect();
        (outs, eng.metrics.prefill_chunks, eng.metrics.mixed_steps)
    };
    let (legacy, _, legacy_mixed) = run(false, 0);
    assert_eq!(legacy.len(), prompts.len());
    assert_eq!(legacy_mixed, 0, "legacy mode must never mix");
    for (cap, min_chunks) in [(0usize, 1), (64, 1), (17, 2)] {
        let (outs, chunks, _) = run(true, cap);
        assert_eq!(legacy, outs,
                   "stream changed with chunking cap {cap}");
        assert!(chunks >= prompts.len() * min_chunks,
                "cap {cap}: only {chunks} chunks");
    }
}

#[test]
fn compiled_chunk_path_matches_per_token_fallback() {
    // PR 4 golden test: the same trace through the compiled
    // chunked-prefill executable (including positionwise-batched
    // groups), the per-token decode fallback, and legacy unchunked mode
    // must emit bit-identical token streams — while the compiled path
    // issues strictly fewer device calls whenever continuation chunks
    // exist. Trace: cold ~40-token prompts (chunked at cap 17) plus
    // warm shared-prefix prompts (suffix chunks).
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(9);
    let prefix: Vec<u32> =
        (0..16).map(|_| (1 + rng.below(511)) as u32).collect();
    let mut prompts: Vec<Vec<u32>> = vec![];
    for i in 0..4u32 {
        prompts.push(
            (0..40u32).map(|t| (i * 53 + t * 17 + 1) % 512).collect(),
        );
        let mut p = prefix.clone();
        p.extend((0..6u32).map(|t| (i * 37 + t * 11 + 1) % 512));
        prompts.push(p);
    }
    let run = |chunked: bool, cap: usize, compiled: bool| {
        let ecfg = EngineConfig {
            block_size: 4,
            enable_chunked_prefill: chunked,
            max_prefill_chunk: cap,
            enable_compiled_chunks: compiled,
            ..Default::default()
        };
        let mut eng = fp16_engine(&m, ecfg);
        for p in &prompts {
            eng.submit(
                p.clone(),
                SamplingParams { max_new_tokens: 6, ..Default::default() },
            );
        }
        eng.run_to_completion(5000).unwrap();
        let mut fin = eng.take_finished();
        fin.sort_by_key(|s| s.id);
        let outs: Vec<Vec<u32>> =
            fin.iter().map(|s| s.output.clone()).collect();
        let st = eng.dep.runtime.stats.borrow().clone();
        assert_eq!(eng.metrics.device_calls, st.device_calls(),
                   "engine metric disagrees with runtime stats");
        (outs, eng.metrics.device_calls, st.chunks)
    };
    let (legacy, _, _) = run(false, 0, true);
    for cap in [0usize, 64, 17] {
        let (outs_c, calls_c, chunk_execs) = run(true, cap, true);
        let (outs_f, calls_f, _) = run(true, cap, false);
        assert_eq!(legacy, outs_c,
                   "compiled stream changed at cap {cap}");
        assert_eq!(legacy, outs_f,
                   "fallback stream changed at cap {cap}");
        if chunk_execs > 0 {
            // the trace has warm suffix chunks at every cap, so the
            // compiled path must save device calls vs the fallback
            assert!(calls_c < calls_f,
                    "cap {cap}: compiled {calls_c} !< fallback {calls_f}");
        }
    }
}

#[test]
fn warm_chunks_batch_positionwise_into_one_call() {
    // Four warm admissions whose suffix chunks share a bucket pair must
    // execute as ONE chunk call (positionwise batching), not four.
    let Some(m) = manifest() else { return };
    let ecfg = EngineConfig { block_size: 4, ..Default::default() };
    let mut eng = fp16_engine(&m, ecfg);
    if eng.dep.runtime.chunk_buckets().is_empty() {
        eprintln!("SKIP: pre-chunk artifacts (rebuild)");
        return;
    }
    let mut rng = sqplus::util::rng::Rng::new(13);
    let prefix: Vec<u32> =
        (0..16).map(|_| (1 + rng.below(511)) as u32).collect();
    // donor registers the shared-prefix blocks
    let mut donor = prefix.clone();
    donor.extend([7, 8, 9, 10]);
    eng.submit(donor,
               SamplingParams { max_new_tokens: 2, ..Default::default() });
    eng.run_to_completion(500).unwrap();
    eng.take_finished();
    let chunks_before = eng.dep.runtime.stats.borrow().chunks;
    // four warm requests land together: each hits 16 cached tokens and
    // runs a [16, 22) suffix chunk — same (chunk_len, prefix) bucket
    for i in 0..4u32 {
        let mut p = prefix.clone();
        p.extend((0..6u32).map(|t| (i * 91 + t * 13 + 1) % 512));
        eng.submit(p, SamplingParams { max_new_tokens: 2,
                                       ..Default::default() });
    }
    let _ = eng.step().unwrap(); // the admission step runs the chunks
    let chunks_after = eng.dep.runtime.stats.borrow().chunks;
    assert_eq!(chunks_after - chunks_before, 1,
               "4 warm chunks should batch into one chunk call");
    // donor's one cold chunk plus the 4 warm suffix chunks
    assert_eq!(eng.metrics.prefill_chunks, 1 + 4);
    eng.run_to_completion(500).unwrap();
    assert_eq!(eng.take_finished().len(), 4);
}

/// Engine on the `small` model (max_len 256 > largest prefill bucket
/// 128) — the configuration where the recompute hazard is real.
fn small_fp16_engine(m: &Manifest, ecfg: EngineConfig) -> Option<Engine> {
    let cfg = ModelConfig::small();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let Ok(rt) = ModelRuntime::load(m, "small", Precision::Fp16, &deploy)
    else {
        eprintln!("SKIP: small artifacts not built");
        return None;
    };
    Some(Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(256)), ecfg,
    ))
}

#[test]
fn preemption_recompute_beyond_largest_bucket_completes() {
    // The recompute hazard, structurally fixed: two 120-token prompts
    // on a pool sized so one is preempted after decoding past the
    // 128-token bucket. Its recompute content (prompt + output > 128)
    // exceeds every compiled prefill bucket — pre-chunking this errored
    // the engine loop ("no prefill bucket"); chunked prefill splits the
    // recompute across a bucket-capped cold chunk plus decode-driven
    // continuation chunks and completes.
    let Some(m) = manifest() else { return };
    let ecfg = EngineConfig {
        block_size: 16,
        total_blocks: 18,
        max_running: 2,
        ..Default::default()
    };
    let Some(mut eng) = small_fp16_engine(&m, ecfg) else { return };
    for i in 0..2u32 {
        eng.submit(
            (0..120u32).map(|t| (i * 131 + t * 7 + 1) % 1024).collect(),
            SamplingParams { max_new_tokens: 60, ..Default::default() },
        );
    }
    eng.run_to_completion(20_000).unwrap();
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 2);
    for f in &fin {
        assert_eq!(f.finish, Some(FinishReason::MaxTokens));
        assert_eq!(f.output.len(), 60, "seq {} truncated", f.id);
    }
    let rep = eng.metrics.report();
    assert!(rep.preemptions > 0, "pool never pressured (test too weak)");
}

#[test]
fn legacy_clamp_keeps_recompute_within_bucket() {
    // Belt-and-braces regression for unchunked mode: the same shape of
    // workload used to error the engine loop when a preempted
    // sequence's prompt+output outgrew the largest bucket. With
    // chunking disabled, admission now clamps max_new_tokens to
    // bucket capacity minus the prompt, so recompute always fits and
    // the trace completes (with correspondingly truncated output).
    let Some(m) = manifest() else { return };
    let ecfg = EngineConfig {
        block_size: 16,
        total_blocks: 15,
        max_running: 2,
        enable_chunked_prefill: false,
        ..Default::default()
    };
    let Some(mut eng) = small_fp16_engine(&m, ecfg) else { return };
    for i in 0..2u32 {
        eng.submit(
            (0..100u32).map(|t| (i * 113 + t * 5 + 1) % 1024).collect(),
            SamplingParams { max_new_tokens: 60, ..Default::default() },
        );
    }
    eng.run_to_completion(20_000).unwrap();
    let fin = eng.take_finished();
    assert_eq!(fin.len(), 2);
    for f in &fin {
        // clamped to bucket (128) - prompt (100) = 28, never errored
        assert_eq!(f.output.len(), 28);
    }
}

#[test]
fn long_prompt_beyond_bucket_serves_chunked() {
    // A prompt longer than every compiled prefill bucket (but within
    // max_len) is rejected by legacy mode and *served* by chunked mode.
    let Some(m) = manifest() else { return };
    let prompt: Vec<u32> =
        (0..160u32).map(|t| (t * 13 + 1) % 1024).collect();
    let legacy = EngineConfig {
        enable_chunked_prefill: false,
        ..Default::default()
    };
    let Some(mut eng) = small_fp16_engine(&m, legacy) else { return };
    eng.submit(prompt.clone(), SamplingParams::default());
    let fin = eng.take_finished();
    assert_eq!(fin[0].finish, Some(FinishReason::PromptTooLong));

    let Some(mut eng) =
        small_fp16_engine(&m, EngineConfig::default()) else { return };
    let id = eng.submit(
        prompt,
        SamplingParams { max_new_tokens: 8, ..Default::default() },
    );
    eng.run_to_completion(5000).unwrap();
    let fin = eng.take_finished();
    let seq = fin.iter().find(|s| s.id == id).unwrap();
    assert_eq!(seq.finish, Some(FinishReason::MaxTokens));
    assert_eq!(seq.output.len(), 8);
    assert!(eng.metrics.prefill_chunks >= 2, "prompt was not chunked");
}

#[test]
fn continuation_chunk_is_one_device_call() {
    // Acceptance: a T-token continuation chunk costs exactly 1 device
    // call on the compiled path. A 160-token prompt on `small` (bucket
    // 128) splits into a cold [0,128) prefill call plus a [128,160)
    // continuation; compiled that is 1 prefill + 1 chunk + 3 decode
    // calls (the first of the 4 outputs samples from the chunk's final
    // logits), while the per-token fallback pays 32 extra decode calls
    // for the same 32-token chunk.
    let Some(m) = manifest() else { return };
    let prompt: Vec<u32> =
        (0..160u32).map(|t| (t * 13 + 1) % 1024).collect();
    let run = |compiled: bool| {
        let ecfg = EngineConfig {
            enable_compiled_chunks: compiled,
            ..Default::default()
        };
        let mut eng = small_fp16_engine(&m, ecfg)?;
        if eng.dep.runtime.chunk_buckets().is_empty() {
            eprintln!("SKIP: pre-chunk artifacts (rebuild)");
            return None;
        }
        eng.submit(
            prompt.clone(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
        eng.run_to_completion(5000).unwrap();
        let fin = eng.take_finished();
        assert_eq!(fin[0].output.len(), 4);
        let st = eng.dep.runtime.stats.borrow().clone();
        assert_eq!(eng.metrics.device_calls, st.device_calls());
        Some((fin[0].output.clone(), st))
    };
    let Some((out_c, st_c)) = run(true) else { return };
    let Some((out_f, st_f)) = run(false) else { return };
    assert_eq!(out_c, out_f, "compiled chunk changed the stream");
    // compiled: one cold prefill, ONE chunk call for the 32-token
    // continuation, one decode call per output after the first
    assert_eq!((st_c.prefills, st_c.chunks, st_c.decodes), (1, 1, 3));
    // fallback: the same continuation costs one decode call per token
    assert_eq!((st_f.prefills, st_f.chunks, st_f.decodes), (1, 0, 35));
}

#[test]
fn multi_replica_router_golden() {
    // PR 5 acceptance golden: the same request trace served by (a) one
    // engine and (b) an N=2 router — cache-aware and round-robin —
    // produces the same token stream per request; the cache-aware
    // router executes strictly fewer cold prefill tokens than
    // round-robin on the shared-prefix burst; and with a sliding
    // eviction window configured, every replica's cached-unreferenced
    // block count stays at/below the high watermark for the whole run.
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(77);
    let prefix: Vec<u32> =
        (0..32).map(|_| (1 + rng.below(511)) as u32).collect();
    // donor (registers the prefix), then a warm burst + cold traffic
    let mut donor = prefix.clone();
    donor.extend([7, 8]);
    let mut burst: Vec<Vec<u32>> = vec![];
    for i in 0..4u32 {
        let mut p = prefix.clone();
        p.extend((0..4u32).map(|t| (i * 37 + t * 11 + 1) % 512));
        burst.push(p);
        burst.push(
            (0..20u32).map(|t| (i * 53 + t * 17 + 1) % 512).collect(),
        );
    }
    let ecfg = EngineConfig { block_size: 4, ..Default::default() };
    let high = 8usize;

    // (a) single engine, same two-phase schedule
    let mut eng = fp16_engine(&m, ecfg.clone());
    let mut single: Vec<(u64, Vec<u32>)> = vec![];
    let id = eng.submit(donor.clone(), SamplingParams {
        max_new_tokens: 2, ..Default::default()
    });
    eng.run_to_completion(2000).unwrap();
    single.extend(eng.take_finished().into_iter()
        .filter(|s| s.id == id).map(|s| (s.id, s.output)));
    for p in &burst {
        eng.submit(p.clone(), SamplingParams {
            max_new_tokens: 4, ..Default::default()
        });
    }
    eng.run_to_completion(5000).unwrap();
    single.extend(eng.take_finished().into_iter()
        .map(|s| (s.id, s.output)));
    single.sort_by_key(|(id, _)| *id);

    // (b) N=2 routers
    let run = |routing: RoutingPolicy| {
        let cores =
            vec![fp16_engine(&m, ecfg.clone()),
                 fp16_engine(&m, ecfg.clone())];
        let mut router = Router::new(cores, RouterConfig {
            routing,
            watermarks: CacheWatermarks::new(high, high / 2),
            load_penalty_tokens: 1,
            ..Default::default()
        });
        let mut fins = vec![];
        let drive = |router: &mut Router<Engine>| {
            while router.has_work() {
                router.step().unwrap();
                for r in router.replicas() {
                    assert!(
                        r.core().cached_unreferenced_blocks() <= high,
                        "sliding window exceeded on replica {}", r.id
                    );
                }
            }
        };
        router.submit(donor.clone(), SamplingParams {
            max_new_tokens: 2, ..Default::default()
        });
        drive(&mut router);
        fins.extend(router.take_finished());
        for p in &burst {
            router.submit(p.clone(), SamplingParams {
                max_new_tokens: 4, ..Default::default()
            });
        }
        drive(&mut router);
        fins.extend(router.take_finished());
        let mut streams: Vec<(u64, Vec<u32>)> = fins
            .iter()
            .map(|f| (f.id, f.seq.output.clone()))
            .collect();
        streams.sort_by_key(|(id, _)| *id);
        let executed: usize = router
            .replicas()
            .iter()
            .map(|r| r.core().metrics.prefill_tokens_executed)
            .sum();
        let routed: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.requests_routed)
            .collect();
        (streams, executed, routed)
    };
    let (ca_streams, ca_exec, ca_routed) = run(RoutingPolicy::CacheAware);
    let (rr_streams, rr_exec, rr_routed) = run(RoutingPolicy::RoundRobin);
    assert_eq!(single, ca_streams,
               "cache-aware router diverged from single engine");
    assert_eq!(single, rr_streams,
               "round-robin router diverged from single engine");
    // both replicas served traffic under round-robin
    assert!(rr_routed.iter().all(|&n| n > 0), "{rr_routed:?}");
    // the warm burst followed the prefix: replica 0 took the donor and
    // every shared-prefix request, so cache-aware must execute strictly
    // fewer cold prefill tokens than round-robin
    assert!(ca_routed[0] > ca_routed[1], "{ca_routed:?}");
    assert!(ca_exec < rr_exec,
            "cache-aware executed {ca_exec} !< round-robin {rr_exec}");
}

/// Drive the shared-prefix evict-then-rehit trace sequentially at the
/// given tiered-pool bound and stash precision: a donor seeds the
/// prefix, a pool-filling stranger demand-evicts every cached block,
/// then the rehit reuses the prefix. Returns (per-request outputs,
/// demotions, restores, recompute-avoided tokens, prefill executed).
fn kv_tier_run(m: &Manifest, pool: usize, mode: KvCacheMode)
    -> (Vec<Vec<u32>>, usize, usize, usize, usize) {
    let prefix: Vec<u32> =
        (0..16u32).map(|t| (t * 29 + 1) % 512).collect();
    let mut donor = prefix.clone();
    donor.extend([7, 8]);
    // needs the whole 12-block pool (44 + 4 generated = 48 slots), so
    // admission demand-evicts everything the donor cached
    let filler: Vec<u32> =
        (0..44u32).map(|t| (t * 31 + 3) % 512).collect();
    let mut rehit = prefix.clone();
    rehit.extend([9, 10, 11]);
    let ecfg = EngineConfig {
        block_size: 4,
        total_blocks: 12,
        kv_cache_mode: mode,
        kv_pool_blocks: pool,
        ..Default::default()
    };
    let mut eng = fp16_engine(m, ecfg);
    let mut outs = vec![];
    for p in [&donor, &filler, &rehit] {
        let id = eng.submit(
            p.clone(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
        eng.run_to_completion(1000).unwrap();
        let fin = eng.take_finished();
        let seq = fin.into_iter().find(|s| s.id == id).unwrap();
        assert_eq!(seq.finish, Some(FinishReason::MaxTokens));
        outs.push(seq.output);
        assert!(eng.kv_pool_len() <= pool, "pool exceeded its bound");
    }
    (outs, eng.metrics.kv_demotions, eng.metrics.kv_restores,
     eng.metrics.recompute_avoided_tokens,
     eng.metrics.prefill_tokens_executed)
}

#[test]
fn tiered_f32_pool_restores_bit_identical_and_saves_prefill() {
    // The F32 identity golden: a tiered restore copies the exact rows
    // the engine stashed, so the evict-then-rehit trace must emit
    // bit-identical streams with the pool on or off — while the tiered
    // run demotes, restores, and executes strictly fewer prefill
    // tokens, with the recompute saving accounted exactly.
    let Some(m) = manifest() else { return };
    let (cold, d0, r0, a0, cold_exec) =
        kv_tier_run(&m, 0, KvCacheMode::F32);
    assert_eq!((d0, r0, a0), (0, 0, 0),
               "tiering counters moved with the pool off");
    let (warm, d1, r1, a1, warm_exec) =
        kv_tier_run(&m, 8, KvCacheMode::F32);
    assert_eq!(cold, warm, "F32 tiered restore changed a stream");
    assert!(d1 > 0, "eviction never demoted");
    assert!(r1 > 0, "rehit never restored from the pool");
    assert_eq!(a1, r1 * 4, "restore accounting must be exact");
    assert!(warm_exec < cold_exec,
            "tiering saved nothing: {warm_exec} !< {cold_exec}");
}

#[test]
fn quantized_kv_tier_restores_with_bounded_token_drift() {
    // The acceptance trace for `--kv-quant q8|q4` + tiering: the rehit
    // restores from the *quantized* pool (recompute-avoided tokens > 0,
    // asserted), and because dequantized KV rows are not bit-identical
    // the gate is task-level: every request still completes with its
    // full budget, and token agreement with the F32 run stays above a
    // width-dependent floor (Q8's grid is 16x finer than Q4's).
    let Some(m) = manifest() else { return };
    let (f32_outs, ..) = kv_tier_run(&m, 8, KvCacheMode::F32);
    let total: usize = f32_outs.iter().map(|o| o.len()).sum();
    for (mode, floor) in
        [(KvCacheMode::Q8, 0.5), (KvCacheMode::Q4, 0.25)]
    {
        let (outs, d, r, a, _) = kv_tier_run(&m, 8, mode);
        assert!(d > 0, "{mode:?}: eviction never demoted");
        assert!(r > 0, "{mode:?}: rehit never restored");
        assert!(a > 0 && a == r * 4,
                "{mode:?}: recompute-avoided accounting broken");
        assert_eq!(outs.len(), f32_outs.len());
        for (o, f) in outs.iter().zip(&f32_outs) {
            assert_eq!(o.len(), f.len(),
                       "{mode:?}: generation budget not honored");
        }
        let agree: usize = outs
            .iter()
            .zip(&f32_outs)
            .map(|(o, f)| {
                o.iter().zip(f.iter()).filter(|(a, b)| a == b).count()
            })
            .sum();
        assert!(agree as f64 >= floor * total as f64,
                "{mode:?}: only {agree}/{total} tokens agree with F32 \
                 (floor {floor})");
    }
}

#[test]
fn auto_kv_pool_blocks_follows_gpu_memory_headroom() {
    let Some(m) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::default());
    let deploy = pipeline::fp16_deploy(&cfg, &w);
    let rt = ModelRuntime::load(&m, "tiny", Precision::Fp16, &deploy)
        .unwrap();
    let dep = Deployment::single(rt, GpuProfile::sim_small(64));
    let blocks = Engine::auto_kv_pool_blocks(&dep, 4);
    // the 8% headroom the device-block budget (92%) leaves, over
    // 4-token blocks of tiny's fp16 KV footprint
    let expect = (64usize << 20) * 8 / 100
        / (4 * ModelConfig::tiny().kv_bytes_per_token());
    assert_eq!(blocks, expect);
    // bigger blocks -> fewer pool slots; the bound never hits zero
    assert!(Engine::auto_kv_pool_blocks(&dep, 64) < blocks);
    assert_eq!(Engine::auto_kv_pool_blocks(&dep, 1 << 24), 1);
}

#[test]
fn kv_migration_matches_warm_replica_across_stash_modes() {
    // Engine-level migration acceptance. A donor engine serves a
    // prefix; its stashed blocks are exported in wire form and
    // imported by a cold receiver. The gate is mode-aware token
    // agreement: the migrated stream must agree token-for-token with
    // what the *warm replica itself* would serve for the same rehit —
    // both sides rebuild KV by decoding the identical stash bytes, so
    // this holds bit-for-bit in every `KvCacheMode`, while agreement
    // with a full f32 recompute is only exact for F32 (quantized
    // drift vs recompute is the tiered-restore acceptance's gate).
    let Some(m) = manifest() else { return };
    let prefix: Vec<u32> =
        (0..16u32).map(|t| (t * 29 + 1) % 512).collect();
    let mut donor_p = prefix.clone();
    donor_p.extend([7, 8]);
    let mut rehit = prefix.clone();
    rehit.extend([9, 10, 11]);
    let gen = |eng: &mut Engine, p: &Vec<u32>| {
        let id = eng.submit(
            p.clone(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        );
        eng.run_to_completion(1000).unwrap();
        let fin = eng.take_finished();
        let seq = fin.into_iter().find(|s| s.id == id).unwrap();
        assert_eq!(seq.finish, Some(FinishReason::MaxTokens));
        seq
    };
    let run = |mode: KvCacheMode| {
        let ecfg = EngineConfig {
            block_size: 4,
            kv_cache_mode: mode,
            kv_pool_blocks: 8,
            ..Default::default()
        };
        let mut a = fp16_engine(&m, ecfg.clone()); // donor
        let mut b = fp16_engine(&m, ecfg.clone()); // receiver
        let mut c = fp16_engine(&m, ecfg); // cold control
        gen(&mut a, &donor_p);
        let blocks = a.export_kv_blocks(&rehit);
        // the 4 full prefix blocks, already in wire precision
        assert_eq!(blocks.len(), 4, "{mode:?}");
        assert_eq!(a.metrics.kv_migrations_out, 4);
        assert!(a.metrics.migrated_bytes > 0);
        let adopted = b.import_kv_blocks(&blocks).unwrap();
        assert_eq!(adopted, 4, "{mode:?}: adoption refused");
        let mig = gen(&mut b, &rehit);
        let warm = gen(&mut a, &rehit);
        let cold = gen(&mut c, &rehit);
        assert_eq!(mig.cached_prefix_len, 16,
                   "{mode:?}: migrated blocks not hit at admission");
        assert_eq!(b.metrics.kv_migrations_in, 4);
        assert_eq!(b.metrics.recompute_avoided_tokens, 16);
        assert!(b.metrics.prefill_tokens_executed
                    < c.metrics.prefill_tokens_executed,
                "{mode:?}: migration saved no prefill");
        (mig.output, warm.output, cold.output)
    };
    for mode in [KvCacheMode::F32, KvCacheMode::Q8, KvCacheMode::Q4] {
        let (mig, warm, cold) = run(mode);
        assert_eq!(mig, warm,
                   "{mode:?}: migrated stream != warm-replica stream");
        assert_eq!(mig.len(), 4);
        if mode == KvCacheMode::F32 {
            // exact rows shipped: recompute parity is bit-level
            assert_eq!(mig, cold, "F32 migration changed the stream");
        } else {
            assert_eq!(cold.len(), 4);
        }
    }
}

#[test]
fn kv_migration_router_golden_f32() {
    // The PR acceptance golden: an N=2 cache-aware router serves a
    // warm-prefix request on the *cold* replica (the warm one is
    // loaded). With --kv-migrate on, the donor's stashed blocks ship
    // to the receiver and only the suffix is recomputed; the control
    // run recomputes everything. Streams — ids, placements, tokens —
    // must match bit-for-bit, while the migrated run executes
    // strictly fewer cold prefill tokens and counts the migration.
    let Some(m) = manifest() else { return };
    let mut rng = sqplus::util::rng::Rng::new(91);
    let prefix: Vec<u32> =
        (0..32).map(|_| (1 + rng.below(511)) as u32).collect();
    let mut donor = prefix.clone();
    donor.extend([7, 8]);
    let blocker: Vec<u32> =
        (0..20u32).map(|t| (t * 17 + 3) % 512).collect();
    let mut warm = prefix.clone();
    warm.extend([9, 10, 11]);
    let ecfg = EngineConfig {
        block_size: 4,
        kv_pool_blocks: 16,
        ..Default::default()
    };
    let run = |kv_migrate: bool| {
        let cores = vec![fp16_engine(&m, ecfg.clone()),
                         fp16_engine(&m, ecfg.clone())];
        let mut router = Router::new(cores, RouterConfig {
            routing: RoutingPolicy::CacheAware,
            // outweighs the 32-token prefix hit, so the warm request
            // lands on the cold replica in BOTH runs — they differ
            // only in how the receiver warms up
            load_penalty_tokens: 33,
            kv_migrate,
            ..Default::default()
        });
        let mut fins = vec![];
        router.submit(donor.clone(), SamplingParams {
            max_new_tokens: 2, ..Default::default()
        });
        while router.has_work() {
            router.step().unwrap();
        }
        fins.extend(router.take_finished());
        // the blocker occupies replica 0 when the warm request places
        router.submit(blocker.clone(), SamplingParams {
            max_new_tokens: 8, ..Default::default()
        });
        router.submit(warm.clone(), SamplingParams {
            max_new_tokens: 4, ..Default::default()
        });
        while router.has_work() {
            router.step().unwrap();
        }
        fins.extend(router.take_finished());
        let mut streams: Vec<(u64, Option<usize>, Vec<u32>)> = fins
            .iter()
            .map(|f| (f.id, f.replica, f.seq.output.clone()))
            .collect();
        streams.sort_by_key(|(id, _, _)| *id);
        let exec: usize = router
            .replicas()
            .iter()
            .map(|r| r.core().metrics.prefill_tokens_executed)
            .sum();
        (streams, exec, router.stats(), router.router_stats())
    };
    let (mig, mig_exec, mig_stats, mig_router) = run(true);
    let (ctl, ctl_exec, ctl_stats, ctl_router) = run(false);
    assert_eq!(mig, ctl, "migration changed a stream or a placement");
    // the warm request was indeed forced off the warm replica
    assert_eq!(mig[2].1, Some(1), "{mig:?}");
    assert!(mig_exec < ctl_exec,
            "migrated run executed {mig_exec} !< control {ctl_exec}");
    assert!(mig_stats[1].core.kv_migrations_in > 0,
            "receiver adopted nothing");
    assert_eq!(mig_stats[1].core.kv_migrations_in,
               mig_stats[0].core.kv_migrations_out);
    assert!(mig_stats[0].core.migrated_bytes > 0);
    assert_eq!(mig_router.migration_fallbacks, 0);
    // with migration off, no counter may move
    assert_eq!(ctl_router.migration_fallbacks, 0);
    for s in &ctl_stats {
        assert_eq!((s.core.kv_migrations_in, s.core.kv_migrations_out,
                    s.core.migrated_bytes), (0, 0, 0));
    }
}

#[test]
fn decode_fills_registered_blocks_warm_later_requests() {
    // Third ROADMAP gap: blocks filled during *decode* seed the cache.
    // A long generation registers its output blocks; a second request
    // whose prompt equals prompt+output of the first hits them.
    let Some(m) = manifest() else { return };
    let ecfg = EngineConfig { block_size: 4, ..Default::default() };
    let mut eng = fp16_engine(&m, ecfg);
    let prompt: Vec<u32> = (0..8u32).map(|t| t * 29 % 512 + 1).collect();
    let id = eng.submit(
        prompt.clone(),
        SamplingParams { max_new_tokens: 12, ..Default::default() },
    );
    eng.run_to_completion(500).unwrap();
    let fin = eng.take_finished();
    let first = fin.iter().find(|s| s.id == id).unwrap();
    assert!(eng.metrics.decode_registered_blocks > 0,
            "decode registered no blocks");
    // second request: prompt = first's prompt + generated output
    let mut warm_prompt = prompt;
    warm_prompt.extend(&first.output);
    let id2 = eng.submit(
        warm_prompt.clone(),
        SamplingParams { max_new_tokens: 4, ..Default::default() },
    );
    eng.run_to_completion(500).unwrap();
    let fin = eng.take_finished();
    let second = fin.iter().find(|s| s.id == id2).unwrap();
    // hit covers all full blocks except the CoW tail: 20 tokens -> 4
    // full blocks (16), last block private
    assert_eq!(second.cached_prefix_len, 16,
               "decode-filled blocks not hit");
}
