//! Property-style integration tests over the quantization library — the
//! invariants DESIGN.md §6 commits to, checked across random models,
//! alphas and shapes (in-tree `prop` harness; proptest is unavailable in
//! the offline build).

use sqplus::config::{KvCacheMode, ModelConfig, QuantConfig, QuantMethod};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, kernel, loss, pipeline, rtn, smooth};
use sqplus::reffwd::{NoHook, RefModel, Site};
use sqplus::runtime::kvq::{quantize_rows, KvStash};
use sqplus::tensor::Tensor;
use sqplus::util::prop;
use sqplus::util::rng::Rng;

fn rand_model(seed: u64, outliers: usize)
    -> (ModelConfig, sqplus::model::store::WeightStore) {
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg,
                         &InitSpec::with_outliers(seed, outliers, 15.0));
    (cfg, w)
}

#[test]
fn prop_smoothing_equivalence_random_alpha() {
    prop::check("smooth equivalence", 6, |rng| {
        let (cfg, w) = rand_model(rng.next_u64(), 1 + rng.below(6));
        let prompts: Vec<Vec<u32>> =
            vec![(0..8).map(|t| (t * 29 + 7) % 512).collect()];
        let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
        let alpha = rng.f32();
        let mut sm = w.clone();
        smooth::smooth_model(&mut sm, &cfg, &cal, alpha);
        let tokens = [5u32, 200, 87, 3];
        let (a, _) = RefModel::new(&cfg, &w).prefill(&tokens, &mut NoHook);
        let (b, _) = RefModel::new(&cfg, &sm).prefill(&tokens, &mut NoHook);
        prop::assert_allclose(&a.data, &b.data, 5e-3, 5e-3,
                              &format!("alpha {alpha}"));
    });
}

#[test]
fn prop_quant_dequant_error_bound() {
    prop::check("rtn 1.5-delta bound", 12, |rng| {
        let k = 128 * (1 + rng.below(3));
        let n = 1 + rng.below(24);
        let loc = (rng.f32() - 0.5) * 8.0;
        let scale = 0.001 + rng.f32() * 4.0;
        let w = Tensor::from_vec(
            &[k, n],
            (0..k * n).map(|_| rng.normal() * scale + loc).collect(),
        );
        let ql = rtn::quantize(&w, 128);
        let deq = ql.dequantize();
        for kk in 0..k {
            for j in 0..n {
                let s = ql.scales.data[(kk / 128) * n + j];
                let e = (deq.data[kk * n + j] - w.data[kk * n + j]).abs();
                assert!(e <= 1.5 * s + 1e-5, "err {e} > 1.5*{s}");
            }
        }
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    prop::check("pack roundtrip", 20, |rng| {
        let k = 2 * (1 + rng.below(128));
        let n = 1 + rng.below(32);
        let q: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let packed = sqplus::quant::pack::pack_nibbles(&q, k, n);
        assert_eq!(sqplus::quant::pack::unpack_nibbles(&packed), q);
    });
}

#[test]
fn prop_kv_roundtrip_error_is_group_bounded() {
    // KV stash quantization inherits the weight quantizer's accuracy
    // contract: per value, |x - dequant(quant(x))| <= 1.5 * the owning
    // group's scale, for both widths, across random dims (odd tails
    // included) and group sizes that don't divide the row evenly
    prop::check("kvq roundtrip bound", 25, |rng| {
        let dim = 1 + rng.below(96);
        let group = 1 + rng.below(dim + 8);
        let nrows = 1 + rng.below(8);
        let scale = 0.01 + rng.f32() * 4.0;
        let loc = (rng.f32() - 0.5) * 2.0;
        let rows: Vec<f32> = (0..nrows * dim)
            .map(|_| rng.normal() as f32 * scale + loc)
            .collect();
        for mode in [KvCacheMode::Q4, KvCacheMode::Q8] {
            let q = quantize_rows(&rows, dim, group, mode);
            let back = q.dequantize_rows();
            assert_eq!(back.len(), rows.len());
            let gpr = dim.div_ceil(group);
            for r in 0..nrows {
                for j in 0..dim {
                    let s = q.scales[r * gpr + j / group];
                    let e = (rows[r * dim + j] - back[r * dim + j]).abs();
                    assert!(e <= 1.5 * s + 1e-5,
                            "{mode:?} row {r} col {j}: err {e} > 1.5*{s}");
                }
            }
        }
    });
}

#[test]
fn prop_kv_pack_roundtrip_on_kv_shapes() {
    // nibble packing must be a bit-exact inverse on KV-stash shapes:
    // [L, 2, block_size, D] flattens to (L*2*block_size) rows of D
    // codes, and even-D stashes pack as one contiguous buffer
    prop::check("kvq pack roundtrip", 20, |rng| {
        let layers = 1 + rng.below(3);
        let bs = 1 + rng.below(16);
        let d = 2 * (1 + rng.below(64));
        let n = layers * 2 * bs * d;
        let q: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
        let packed = sqplus::quant::pack::pack_nibbles(&q, n, 1);
        assert_eq!(packed.data.len(), n / 2);
        assert_eq!(sqplus::quant::pack::unpack_nibbles(&packed), q);
    });
}

#[test]
fn prop_kv_byte_accounting_is_exact() {
    // QuantKvBlock::bytes() must equal the closed-form footprint:
    // codes (packed nibbles or bytes) + one f32 (scale, zero) pair per
    // group — the number the tiered pool's occupancy accounting trusts
    prop::check("kvq byte accounting", 25, |rng| {
        let dim = 1 + rng.below(80);
        let group = 1 + rng.below(dim + 4);
        let nrows = 1 + rng.below(10);
        let rows: Vec<f32> =
            (0..nrows * dim).map(|_| rng.normal() as f32).collect();
        let gpr = dim.div_ceil(group);
        let q4 = quantize_rows(&rows, dim, group, KvCacheMode::Q4);
        assert_eq!(q4.bytes(),
                   nrows * dim.div_ceil(2) + 4 * 2 * (nrows * gpr));
        let q8 = quantize_rows(&rows, dim, group, KvCacheMode::Q8);
        assert_eq!(q8.bytes(), nrows * dim + 4 * 2 * (nrows * gpr));
        assert!(q4.bytes() < q8.bytes() || dim == 1,
                "q4 must be smaller for dim > 1");
        assert_eq!(KvStash::F32(rows).bytes(), 4 * nrows * dim);
    });
}

#[test]
fn prop_deploy_store_dequantizes_to_effective() {
    // deploy (packed) and effective (fake-quant) stores must describe the
    // same weights: unpack+dequant(deploy) == effective, exactly.
    let (cfg, w) = rand_model(3, 4);
    let prompts: Vec<Vec<u32>> = vec![(0..8).map(|t| (t * 13) % 512)
        .collect()];
    let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
    let out = pipeline::quantize_model(&cfg, &w, &cal, QuantMethod::Rtn,
                                       &QuantConfig::default());
    let deploy = out.deploy.unwrap();
    for layer in 0..cfg.layers {
        for lin in sqplus::model::LAYER_LINEARS {
            let base = format!("layers.{layer}.{lin}");
            let ql = rtn::QuantizedLinear {
                packed: deploy.u8(&format!("{base}.packed")).clone(),
                scales: deploy.f32(&format!("{base}.scales")).clone(),
                zeros: deploy.f32(&format!("{base}.zeros")).clone(),
                group_size: cfg.group_size,
            };
            let deq = ql.dequantize();
            prop::assert_allclose(&deq.data,
                                  &out.effective.f32(&base).data,
                                  1e-6, 1e-6, &base);
        }
    }
}

#[test]
fn prop_smoothed_quant_loss_never_worse_than_best_extreme() {
    // the searched alpha's loss is <= both endpoint losses (alpha=0, 1)
    prop::check("search optimality on grid", 3, |rng| {
        let (cfg, w) = rand_model(rng.next_u64(), 4);
        let prompts: Vec<Vec<u32>> =
            vec![(0..10).map(|t| (t * 31 + 11) % 512).collect()];
        let cal = calib::collect(&cfg, &w, &prompts, 24, 0);
        let qcfg = QuantConfig::default();
        let r = sqplus::quant::search::search_alpha(&cfg, &w, &cal, &qcfg);
        let l0 = r.grid.first().unwrap().1;
        let l1 = r.grid.last().unwrap().1;
        assert!(r.loss <= l0 + 1e-9 && r.loss <= l1 + 1e-9,
                "searched {} vs endpoints {l0}, {l1}", r.loss);
    });
}

#[test]
fn prop_calib_stats_are_upper_bounds() {
    // absmax from calibration really bounds the activations of the same
    // prompts (self-consistency of the collector)
    let (cfg, w) = rand_model(9, 4);
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..9).map(|t| (i * 67 + t * 23) % 512).collect())
        .collect();
    let cal = calib::collect(&cfg, &w, &prompts, 1024, 0);
    // recollect and compare: deterministic forward => identical stats
    let cal2 = calib::collect(&cfg, &w, &prompts, 1024, 0);
    for layer in 0..cfg.layers {
        for site in Site::all() {
            let a = cal.stats(layer, site);
            let b = cal2.stats(layer, site);
            prop::assert_allclose(&a.absmax, &b.absmax, 1e-6, 1e-7,
                                  "absmax deterministic");
            // retained rows obey the bound
            let (r, c) = (a.rows.shape[0], a.rows.shape[1]);
            for i in 0..r {
                for j in 0..c {
                    assert!(a.rows.data[i * c + j].abs()
                        <= a.absmax[j] + 1e-5);
                }
            }
        }
    }
}

#[test]
fn prop_w4a16_kernel_matches_dequant_matmul() {
    // the fused kernel computes x @ dequant(Wq) straight from packed
    // nibbles; it must agree with the explicit dequantize-then-matmul
    // reference within 1e-4 across random shapes and group sizes
    prop::check("w4a16 kernel == dequant matmul", 12, |rng| {
        let g = 1 + rng.below(16);
        let mut k = g * (1 + rng.below(6));
        if k % 2 == 1 {
            k *= 2;
        }
        let n = 1 + rng.below(40);
        let m = 1 + rng.below(9);
        let scale = 0.05 + rng.f32() * 3.0;
        let w = Tensor::from_vec(
            &[k, n],
            (0..k * n).map(|_| rng.normal() * scale).collect(),
        );
        let x = Tensor::from_vec(
            &[m, k],
            (0..m * k).map(|_| rng.normal()).collect(),
        );
        let q = rtn::quantize(&w, g);
        let got = kernel::matmul_w4a16(&x, &q);
        let want = x.matmul(&q.dequantize());
        assert_eq!(got.shape, want.shape);
        // per-element: tolerance anchored on the output's RMS magnitude
        let rms = ((want.frob_sq() / want.numel().max(1) as f64).sqrt()
            as f32)
            .max(1e-6);
        prop::assert_allclose(&got.data, &want.data, 3e-4, 3e-4 * rms,
                              "kernel elementwise");
        // global: within 1e-4 relative in Frobenius norm
        let rel =
            got.sq_diff(&want).sqrt() / want.frob_sq().sqrt().max(1e-12);
        assert!(rel < 1e-4, "rel frobenius err {rel}");
    });
}

#[test]
fn prop_fused_quant_loss_bit_for_bit_on_tiny_model() {
    // the fused quant_loss must reproduce the pre-fusion
    // clone → scale → fake-quant → unscale → linear_loss pipeline
    // exactly on the seed ModelConfig::tiny() setup, for every
    // (layer, site, consumer) and across alphas and clip ratios
    let cfg = ModelConfig::tiny();
    let w = init_weights(&cfg, &InitSpec::with_outliers(1, 4, 60.0));
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..10).map(|t| (i * 101 + t * 17) % 512).collect())
        .collect();
    let cal = calib::collect(&cfg, &w, &prompts, 24, 0);
    for alpha in [0.0f32, 0.35, 0.5, 1.0] {
        for layer in 0..cfg.layers {
            for site in Site::all() {
                let stats = cal.stats(layer, site);
                let wmax = smooth::unit_weight_absmax(&w, layer, site);
                let s =
                    smooth::smoothing_factors(&stats.absmax, &wmax, alpha);
                for lin in site.consumers() {
                    let name = format!("layers.{layer}.{lin}");
                    let orig = w.f32(&name);
                    for clip in [1.0f32, 0.9] {
                        let mut scaled = orig.clone();
                        scaled.scale_rows(&s);
                        let mut eff = rtn::quantize_clipped(
                            &scaled, cfg.group_size, clip)
                            .dequantize();
                        let inv: Vec<f32> =
                            s.iter().map(|&v| 1.0 / v).collect();
                        eff.scale_rows(&inv);
                        let want =
                            loss::linear_loss(&stats.rows, orig, &eff);
                        let got = loss::quant_loss(
                            &stats.rows, orig, Some(&s), cfg.group_size,
                            clip,
                        );
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "{name} alpha={alpha} clip={clip}: \
                             {got} vs {want}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_awq_and_sqplus_preserve_model_function() {
    let mut rng = Rng::new(77);
    for _ in 0..2 {
        let (cfg, w) = rand_model(rng.next_u64(), 3);
        let prompts: Vec<Vec<u32>> =
            vec![(0..8).map(|t| (t * 41 + 3) % 512).collect()];
        let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
        let tokens = [9u32, 100, 55];
        let (want, _) =
            RefModel::new(&cfg, &w).prefill(&tokens, &mut NoHook);
        for method in [QuantMethod::Awq, QuantMethod::SmoothQuantPlus] {
            let out = pipeline::quantize_model(&cfg, &w, &cal, method,
                                               &QuantConfig::default());
            let (got, _) = RefModel::new(&cfg, &out.effective)
                .prefill(&tokens, &mut NoHook);
            // quantized model stays in the same ballpark (sanity; the
            // tight accuracy statements live in the eval benches)
            let rel = got.sq_diff(&want).sqrt() / want.frob_sq().sqrt();
            assert!(rel < 0.5, "{method:?} rel err {rel}");
        }
    }
}
